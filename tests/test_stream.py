"""Streaming cohort engine equivalence (DESIGN.md §12).

``engine="stream"`` must be the SAME algorithm as the dense scan engine for
every registered algorithm: the inner chunk scan re-associates the additive
moment sums at chunk boundaries (allclose, rtol 1e-5; bit-exact when one
chunk covers the cohort, because the computation degenerates to the dense
moments path), but all randomness — per-client LDP noise rows and PrivUnit
keys (global-index fold_in), the sampling mask, post-reduction CDP noise and
xi (replicated round key), adaptive-clip bit noise — derives identically.

Coverage demanded by the §12 contract: all registry algorithms plus the §11
cross-products, M % chunk_clients != 0 (ragged grid → zero-weight padding),
sampled cohorts whose chunks can be entirely empty, sharded+streamed (each
shard streams its slice; runs 1- and 8-device under the CI matrix), and
kill/resume mid-run through the checkpoint machinery.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (
    materialize_ldp_noise,
    partial_clip_moments,
    streamed_clip_moments,
)
from repro.core.compose import (
    FedEXPStep,
    GaussianLDP,
    WeightedAggregation,
    compose_algorithm,
)
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import linreg_loss, make_synthetic_linreg
from repro.fedsim import (
    CohortSpec,
    EngineSpec,
    FederatedSession,
    LocalSpec,
    ShardSpec,
    StreamSpec,
    TrainSpec,
    chunk_cohort,
)
from repro.kernels.dp_aggregate.ops import (
    dp_aggregate_sums,
    dp_aggregate_sums_chunked,
)
from repro.launch.mesh import make_client_mesh

# M deliberately not divisible by the 16-client chunk (44 % 16 = 12): every
# parity test exercises the ragged tail of the chunk grid.
M, D, TAU, ETA_L, ROUNDS, CHUNK = 44, 24, 2, 0.1, 4, 16

ALG_KWARGS = {
    "fedavg": {},
    "fedexp": {},
    "dp-fedavg-ldp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "ldp-fedexp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "dp-fedavg-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "ldp-fedexp-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.05),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
    # §11 cross-products (no monolithic counterpart)
    "ldp-gauss-fedadam": dict(clip_norm=0.3, sigma=0.21, server_lr=0.05),
    "cdp-fedmom": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "privunit-fedexp-adaptive-clip": dict(eps0=2.0, eps1=2.0, eps2=2.0, dim=D,
                                          c0=0.5),
}

KEY = jax.random.PRNGKey(11)

# the full-registry sweep is this file's heaviest block: these two
# representatives (one LDP, one CDP mechanism) stay unmarked so a local
# `-m "not slow"` run still covers the stream==dense parity PATH, while the
# remaining registry names carry the `slow` marker (CI runs the full matrix)
FAST_PARITY = ("ldp-fedexp-gauss", "cdp-fedexp")


def _sweep(names):
    return [n if n in FAST_PARITY else pytest.param(n, marks=pytest.mark.slow)
            for n in names]


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data.client_batches(), jnp.zeros(D)


def _session(problem, name, *, engine=None, stream=None, cohort=None,
             shard=None, local=None, rounds=ROUNDS):
    batches, w0 = problem
    kw = {}
    if engine is not None:
        kw["engine"] = engine
    if stream is not None:
        kw["stream"] = stream
    if cohort is not None:
        kw["cohort"] = cohort
    if shard is not None:
        kw["shard"] = shard
    if local is not None:
        kw["local"] = local
    alg = make_algorithm(name, **ALG_KWARGS[name])
    return FederatedSession(alg, linreg_loss, w0, batches,
                            train=TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L),
                            **kw)


def _stream_spec(chunk=CHUNK):
    return dict(engine=EngineSpec(engine="stream"),
                stream=StreamSpec(chunk_clients=chunk))


def _assert_runs_close(a, b, rtol=1e-5, atol=1e-6):
    np.testing.assert_allclose(np.asarray(a.final_w), np.asarray(b.final_w),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(a.last_w), np.asarray(b.last_w),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(a.eta_history),
                               np.asarray(b.eta_history),
                               rtol=rtol, atol=atol)


class TestStreamEquivalence:
    @pytest.mark.parametrize("name", _sweep(sorted(ALG_KWARGS)))
    def test_stream_matches_dense(self, problem, name):
        """All registry algorithms + §11 cross-products, ragged chunk grid."""
        dense = _session(problem, name).run(KEY)
        stream = _session(problem, name, **_stream_spec()).run(KEY)
        _assert_runs_close(stream, dense)

    def test_single_chunk_is_bit_exact_on_moments_path(self, problem):
        """chunk_clients >= M degenerates to ONE chunk: on the sampled round
        path (dense also routes through local_moments there) the streamed
        computation is the identical program — bit-for-bit, not just close."""
        cohort = CohortSpec(size=9)
        dense = _session(problem, "ldp-fedexp-gauss", cohort=cohort).run(KEY)
        stream = _session(problem, "ldp-fedexp-gauss", cohort=cohort,
                          **_stream_spec(chunk=64)).run(KEY)
        np.testing.assert_array_equal(np.asarray(stream.final_w),
                                      np.asarray(dense.final_w))
        np.testing.assert_array_equal(np.asarray(stream.eta_history),
                                      np.asarray(dense.eta_history))

    def test_weighted_aggregation_streams(self, problem):
        """Per-client weights slice by GLOBAL index inside every chunk, and
        the weight-sum count stays traced (no static substitution)."""
        batches, w0 = problem
        alg = compose_algorithm(
            GaussianLDP(0.3, 0.21), FedEXPStep(),
            WeightedAggregation(weights=tuple(float(i % 3 + 1)
                                              for i in range(M))))
        train = TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L)
        dense = FederatedSession(alg, linreg_loss, w0, batches,
                                 train=train).run(KEY)
        stream = FederatedSession(alg, linreg_loss, w0, batches, train=train,
                                  **_stream_spec()).run(KEY)
        _assert_runs_close(stream, dense)

    def test_localspec_trainer_streams(self):
        """Minibatch/momentum clients shuffle by GLOBAL client index, so the
        spec trainer is chunk-position-independent."""
        samples = jax.random.normal(jax.random.PRNGKey(7), (M, 16, D))

        def sample_loss(w, b):
            return 0.5 * jnp.mean(jnp.sum(jnp.square(w - b), -1))

        w0 = jnp.zeros(D)
        alg = make_algorithm("ldp-fedexp-gauss", clip_norm=0.3, sigma=0.21)
        train = TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L)
        local = LocalSpec(batch_size=4, epochs=2, momentum=0.5)
        dense = FederatedSession(alg, sample_loss, w0, samples, train=train,
                                 local=local).run(KEY)
        stream = FederatedSession(alg, sample_loss, w0, samples, train=train,
                                  local=local, **_stream_spec()).run(KEY)
        _assert_runs_close(stream, dense)

    def test_pytree_model_streams(self):
        """Pytree params ravel once at the session boundary; the chunk grid
        only ever sees the flat vectors."""
        params = {"W": jnp.zeros((4, 3)), "b": jnp.zeros(3)}
        batches = {"x": jax.random.normal(jax.random.PRNGKey(0), (M, 8, 4)),
                   "y": jax.random.normal(jax.random.PRNGKey(1), (M, 8, 3))}

        def loss(p, b):
            err = b["x"] @ p["W"] + p["b"] - b["y"]
            return 0.5 * jnp.mean(jnp.sum(err ** 2, -1))

        alg = make_algorithm("cdp-fedexp", clip_norm=0.3, sigma=0.05,
                             num_clients=M)
        train = TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L)
        dense = FederatedSession(alg, loss, params, batches, train=train).run(KEY)
        stream = FederatedSession(alg, loss, params, batches, train=train,
                                  **_stream_spec()).run(KEY)
        np.testing.assert_allclose(np.asarray(stream.final_w["W"]),
                                   np.asarray(dense.final_w["W"]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(stream.final_w["b"]),
                                   np.asarray(dense.final_w["b"]),
                                   rtol=1e-5, atol=1e-6)


class TestStreamSampling:
    @pytest.mark.parametrize("cohort", [
        CohortSpec(q=0.3),                  # Bernoulli, can empty a chunk
        CohortSpec(size=5),                 # 5 of 44: most chunks are empty
        CohortSpec(size=5, replace=True),   # multiplicity-weighted
    ], ids=["bernoulli", "fixed", "with-replacement"])
    def test_sampled_stream_matches_dense(self, problem, cohort):
        dense = _session(problem, "ldp-fedexp-gauss", cohort=cohort).run(KEY)
        stream = _session(problem, "ldp-fedexp-gauss", cohort=cohort,
                          **_stream_spec()).run(KEY)
        _assert_runs_close(stream, dense)
        assert np.all(np.isfinite(np.asarray(stream.final_w)))

    def test_empty_round_is_finite(self, problem):
        """A Bernoulli round that samples nobody leaves every chunk empty;
        the clamped count turns the round into a no-op, never NaN."""
        cohort = CohortSpec(q=0.01)
        stream = _session(problem, "cdp-fedexp", cohort=cohort,
                          **_stream_spec(), rounds=8).run(KEY)
        assert np.all(np.isfinite(np.asarray(stream.final_w)))
        assert np.all(np.isfinite(np.asarray(stream.eta_history)))


class TestStreamSharded:
    """Each shard streams its own slice (1 device locally, 8 on the CI leg)."""

    @pytest.fixture(scope="class")
    def mesh(self):
        return make_client_mesh()

    @pytest.mark.parametrize("name", ["ldp-fedexp-gauss", "cdp-fedexp",
                                      "cdp-fedexp-adaptive-clip",
                                      "ldp-fedexp-privunit"])
    def test_sharded_stream_matches_dense(self, problem, mesh, name):
        dense = _session(problem, name).run(KEY)
        stream = _session(problem, name, shard=ShardSpec(mesh=mesh),
                          **_stream_spec()).run(KEY)
        _assert_runs_close(stream, dense)

    def test_sharded_sampled_stream(self, problem, mesh):
        """Sampling masks derive from the replicated round key: sharded,
        streamed, AND sampled still sees the dense engine's exact cohort."""
        cohort = CohortSpec(q=0.4)
        dense = _session(problem, "ldp-fedexp-gauss", cohort=cohort).run(KEY)
        stream = _session(problem, "ldp-fedexp-gauss", cohort=cohort,
                          shard=ShardSpec(mesh=mesh), **_stream_spec()).run(KEY)
        _assert_runs_close(stream, dense)


class TestStreamResume:
    def test_kill_resume_bit_exact(self, problem):
        """Streamed runs checkpoint/resume through the same carry machinery:
        resuming a killed run reproduces the uninterrupted run bit-for-bit
        (same chunk grids, same fold_in(key, t) round keys)."""
        batches, w0 = problem
        alg = make_algorithm("cdp-fedexp-adaptive-clip", **ALG_KWARGS[
            "cdp-fedexp-adaptive-clip"])

        def session(rounds):
            return FederatedSession(
                alg, linreg_loss, w0, batches,
                train=TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L),
                **_stream_spec())

        with tempfile.TemporaryDirectory() as tmp:
            full = session(ROUNDS).run(KEY, checkpoint_dir=tmp + "/full",
                                       checkpoint_every=2)
            session(2).run(KEY, checkpoint_dir=tmp + "/killed",
                           checkpoint_every=2)  # "killed" after round 2
            resumed = session(ROUNDS).resume(tmp + "/killed")
        np.testing.assert_array_equal(np.asarray(resumed.final_w),
                                      np.asarray(full.final_w))
        np.testing.assert_array_equal(np.asarray(resumed.eta_history),
                                      np.asarray(full.eta_history))


class TestStreamSpecValidation:
    def test_chunk_grid_shapes(self, problem):
        batches, _ = problem
        grid, mask = chunk_cohort(batches, CHUNK)
        n_chunks = -(-M // CHUNK)
        leaves = jax.tree_util.tree_leaves(grid)
        assert mask.shape == (n_chunks, CHUNK)
        assert all(x.shape[:2] == (n_chunks, CHUNK) for x in leaves)
        assert float(jnp.sum(mask)) == M  # padding rows are zero-weight
        flat = mask.reshape(-1)
        np.testing.assert_array_equal(np.asarray(flat[:M]), 1.0)
        np.testing.assert_array_equal(np.asarray(flat[M:]), 0.0)

    def test_chunk_grid_divides_by_shards(self, problem):
        batches, _ = problem
        _, mask = chunk_cohort(batches, 16, n_shards=4)
        assert mask.size % (16 * 4) == 0

    def test_stream_spec_validates(self):
        with pytest.raises(ValueError):
            StreamSpec(chunk_clients=0)
        with pytest.raises(ValueError):
            EngineSpec(engine="streaming")  # only "stream" is the §12 engine

    def test_non_stream_engine_rejects_stream_spec(self, problem):
        batches, w0 = problem
        alg = make_algorithm("fedavg")
        with pytest.raises(ValueError, match="engine='stream'"):
            FederatedSession(alg, linreg_loss, w0, batches,
                             train=TrainSpec(rounds=2, tau=1, eta_l=0.1),
                             stream=StreamSpec(chunk_clients=8))

    def test_run_batched_streams_seeds_sequentially(self, problem):
        """The streamed seed sweep reuses ONE compiled stream program across
        seeds and matches per-seed run() bit-for-bit, with every RunResult
        field gaining the leading (S,) axis."""
        batches, w0 = problem
        alg = make_algorithm("fedexp")
        session = FederatedSession(alg, linreg_loss, w0, batches,
                                   train=TrainSpec(rounds=2, tau=1, eta_l=0.1),
                                   **_stream_spec())
        keys = jax.random.split(jax.random.PRNGKey(5), 3)
        batched = session.run_batched(keys)
        assert batched.final_w.shape == (3, D)
        assert batched.eta_history.shape == (3, 2)
        for i in range(3):
            single = session.run(keys[i])
            np.testing.assert_array_equal(np.asarray(batched.final_w[i]),
                                          np.asarray(single.final_w))
            np.testing.assert_array_equal(np.asarray(batched.eta_history[i]),
                                          np.asarray(single.eta_history))

    def test_run_batched_stream_rejects_batched_axes(self, problem):
        batches, w0 = problem
        alg = make_algorithm("fedavg")
        session = FederatedSession(alg, linreg_loss, w0, batches,
                                   train=TrainSpec(rounds=2, tau=1, eta_l=0.1),
                                   engine=EngineSpec(engine="stream"))
        with pytest.raises(ValueError, match="per-seed"):
            session.run_batched(jnp.stack([KEY, KEY]), batched_w0=True)


class TestChunkedAggregation:
    """The chunked reduction entry points under the engine (DESIGN.md §12)."""

    def setup_method(self):
        self.u = jax.random.normal(jax.random.PRNGKey(0), (M, D))
        self.noise = materialize_ldp_noise(jax.random.PRNGKey(1), M, D, 0.2)
        self.mask = jax.random.bernoulli(
            jax.random.PRNGKey(2), 0.6, (M,)).astype(jnp.float32)

    @pytest.mark.parametrize("chunk", [7, 16, M, 100])
    def test_streamed_clip_moments_matches_dense(self, chunk):
        dense = partial_clip_moments(self.u, 0.3, self.noise,
                                     weight_mask=self.mask)
        s = streamed_clip_moments(self.u, 0.3, self.noise,
                                  chunk_clients=chunk, weight_mask=self.mask)
        np.testing.assert_allclose(np.asarray(s.sum_c), np.asarray(dense.sum_c),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(s.sum_sq), float(dense.sum_sq),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(s.sum_sq_clipped),
                                   float(dense.sum_sq_clipped), rtol=1e-5)
        assert float(s.count) == float(dense.count)

    def test_streamed_clip_moments_weighted(self):
        w = jnp.arange(1.0, M + 1.0)
        dense = partial_clip_moments(self.u, 0.3, None, weight_mask=self.mask,
                                     row_weights=w)
        s = streamed_clip_moments(self.u, 0.3, None, chunk_clients=10,
                                  weight_mask=self.mask, row_weights=w)
        np.testing.assert_allclose(np.asarray(s.sum_c), np.asarray(dense.sum_c),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(s.count), float(dense.count),
                                   rtol=1e-6)

    def test_streamed_unmasked_static_count(self):
        s = streamed_clip_moments(self.u, 0.3, None, chunk_clients=11)
        assert float(s.count) == M

    def test_kernel_sums_chunked_matches_dense(self):
        dense = dp_aggregate_sums(self.u, 0.3, self.noise)
        chunked = dp_aggregate_sums_chunked(self.u, 0.3, self.noise,
                                            chunk_m=11)
        for a, b in zip(chunked, dense):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)

    def test_kernel_sums_chunked_rejects_ragged(self):
        with pytest.raises(ValueError, match="multiple of chunk_m"):
            dp_aggregate_sums_chunked(self.u, 0.3, None, chunk_m=13)


class TestStreamScalesPastDense:
    def test_large_cohort_small_chunk(self):
        """A cohort far bigger than the chunk completes with chunk-bounded
        update memory and matches the dense engine on the same geometry."""
        m, d, chunk = 3000, 32, 256
        targets = jax.random.normal(jax.random.PRNGKey(5), (m, d))

        def quad_loss(w, b):
            return 0.5 * jnp.sum(jnp.square(w - b))

        alg = make_algorithm("ldp-fedexp-gauss", clip_norm=0.3, sigma=0.21)
        train = TrainSpec(rounds=2, tau=1, eta_l=0.5)
        w0 = jnp.zeros(d)
        dense = FederatedSession(alg, quad_loss, w0, targets,
                                 train=train).run(KEY)
        stream = FederatedSession(alg, quad_loss, w0, targets, train=train,
                                  **_stream_spec(chunk=chunk)).run(KEY)
        _assert_runs_close(stream, dense, rtol=1e-5, atol=1e-5)
