"""Roofline-layer unit tests (pure python, no jax compilation)."""
from repro.launch.roofline import HW, Hardware, collective_bytes, model_flops, roofline_terms


class TestTerms:
    def test_terms_and_bottleneck(self):
        t = roofline_terms(197e12, 819e9, 50e9)  # exactly 1 second each
        assert abs(t["compute_s"] - 1.0) < 1e-9
        assert abs(t["memory_s"] - 1.0) < 1e-9
        assert abs(t["collective_s"] - 1.0) < 1e-9

    def test_bottleneck_selection(self):
        assert roofline_terms(1e15, 1e9, 1e6)["bottleneck"] == "compute_s"
        assert roofline_terms(1e9, 1e13, 1e6)["bottleneck"] == "memory_s"
        assert roofline_terms(1e9, 1e9, 1e12)["bottleneck"] == "collective_s"

    def test_custom_hardware(self):
        hw = Hardware(peak_flops=100.0, hbm_bw=10.0, ici_bw=1.0)
        t = roofline_terms(200.0, 20.0, 3.0, hw)
        assert t["compute_s"] == 2.0 and t["memory_s"] == 2.0 and t["collective_s"] == 3.0


class TestModelFlops:
    def test_train_vs_serve(self):
        assert model_flops(10, 10, 100, "train") == 6 * 10 * 100
        assert model_flops(10, 10, 100, "decode") == 2 * 10 * 100
        assert model_flops(10, 10, 100, "prefill") == 2 * 10 * 100

    def test_moe_uses_active(self):
        # N total is informational; active drives the count
        assert model_flops(1000, 17, 5, "train") == 6 * 17 * 5


class TestLegacyCollectiveParse:
    def test_simple_module(self):
        hlo = """
HloModule m
ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %ar = f32[4]{0} all-reduce(%p), replica_groups={}, to_apply=%add
  %ag = f32[8]{0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[4]{0} slice(%ag), slice={[0:4]}
}
"""
        got = collective_bytes(hlo)
        assert got["all-reduce"] == 16
        assert got["all-gather"] == 32
        assert got["all-to-all"] == 0

    def test_done_not_double_counted(self):
        hlo = """
ENTRY %main () -> f32[4] {
  %s = (f32[4]{0}, f32[4]{0}) all-reduce-start(%x)
  %d = f32[4]{0} all-reduce-done(%s)
}
"""
        got = collective_bytes(hlo)
        # -start counted once (result tuple), -done skipped
        assert got["all-reduce"] == 32
