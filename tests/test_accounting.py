"""Privacy accounting (Props. 4.1/4.2, GDP numerics, PrivUnit pure DP)."""
import math

import pytest

from repro.core import accounting as acc


class TestGDP:
    def test_delta_eps_inverse(self):
        for mu in (0.1, 1.0, 3.0):
            eps = acc.gdp_epsilon(mu, 1e-5)
            assert abs(acc.gdp_delta(mu, eps) - 1e-5) < 1e-8

    def test_monotone_in_mu(self):
        es = [acc.gdp_epsilon(mu, 1e-5) for mu in (0.5, 1.0, 2.0, 4.0)]
        assert es == sorted(es)

    def test_known_value(self):
        # mu = 1 GDP at delta=1e-5 is eps ~ 3.9-4.0 (Balle-Wang / Dong et al.)
        eps = acc.gdp_epsilon(1.0, 1e-5)
        assert 3.5 < eps < 4.5

    def test_rdp_upper_bounds_gdp(self):
        """RDP conversion is looser than the exact analytic curve."""
        for c, sigma in ((1.0, 0.7), (0.3, 1.5)):
            r = acc.ldp_gaussian_budget(c, sigma, 1e-5)
            assert r.eps_rdp >= r.eps_numerical


class TestSubsampling:
    def test_q1_is_exact_composition(self):
        assert acc.subsampled_gdp_mu(0.3, 1.0, 25) == pytest.approx(
            0.3 * math.sqrt(25))

    def test_amplification_tightens_with_q(self):
        mus = [acc.subsampled_gdp_mu(0.3, q, 50) for q in (0.05, 0.25, 0.5)]
        assert mus == sorted(mus)
        # small mu_round: e^{mu^2}-1 ~ mu^2, so mu_total ~ q*mu*sqrt(T)
        assert mus[0] == pytest.approx(0.05 * 0.3 * math.sqrt(50), rel=0.05)

    def test_cdp_budget_sampling_q(self):
        """sampling_q models the engine's count-normalized release: the
        conditional per-round mu is the full-participation mu / q, then the
        CLT composes at rate q; the amplification at best cancels the
        inflation (no naive q-discount)."""
        c, sigma, m, t, q = 0.3, 0.05, 400, 30, 0.25
        full = acc.cdp_budget(c, sigma, m, t, 1e-5)
        samp = acc.cdp_budget(c, sigma, m, t, 1e-5, sampling_q=q)
        assert "q=0.25" in samp.setting
        mu_round = 2 * c / (sigma * math.sqrt(m)) / q
        assert samp.mu == pytest.approx(acc.subsampled_gdp_mu(mu_round, q, t))
        assert samp.eps_numerical >= 0.9 * full.eps_numerical  # no free lunch
        # the amplification term IS doing work: the inflated conditional
        # release composed naively (no subsampling credit) would cost more
        # whenever mu_round is small enough for the CLT to bite
        small = acc.subsampled_gdp_mu(0.02, 0.5, 30)
        assert small < 0.02 * math.sqrt(30)

    def test_tiny_q_reports_inf_instead_of_overflowing(self):
        # the 1/q-inflated conditional mu overflows exp at small q: the
        # budget must come back inf, not raise OverflowError
        assert acc.subsampled_gdp_mu(60.0, 0.01, 30) == float("inf")
        r = acc.cdp_budget(0.3, 0.05, 400, 30, 1e-5, sampling_q=0.01)
        assert r.mu == float("inf") and r.eps_numerical == float("inf")

    def test_default_q_matches_pre_sampling_numbers(self):
        # sampling_q=1.0 must not perturb Proposition 4.2's reported budget
        r = acc.cdp_budget(0.3, 0.05, 400, 30, 1e-5, sigma_xi=0.01)
        mu_mean = 2 * 0.3 / (0.05 * math.sqrt(400))
        mu_xi = 0.3**2 / (400 * 0.01)
        mu = math.sqrt(30 * (mu_mean**2 + mu_xi**2))
        assert r.mu == pytest.approx(mu)


class TestPaperBudgets:
    def test_ldp_gaussian_paper_setting(self):
        """Paper Table 1: sigma = 0.7*C gives eps ~ 15.66 at delta=1e-5."""
        r = acc.ldp_gaussian_budget(1.0, 0.7, 1e-5)
        assert abs(r.eps_numerical - 15.659) < 0.2

    def test_privunit_paper_setting(self):
        r = acc.privunit_budget(2.0, 2.0, 2.0)
        assert r.eps_numerical == 6.0
        assert r.delta == 0.0

    def test_cdp_fedexp_overhead_negligible(self):
        """Table 1: CDP-FedEXP eps barely exceeds DP-FedAvg with sigma_xi=d sigma^2/M."""
        m, t, c, delta = 1000, 50, 1.0, 1e-5
        sigma = 5.0 * c / math.sqrt(m)
        d = 5046  # the paper's CDP CNN dimension
        sigma_xi = d * sigma**2 / m
        base = acc.cdp_budget(c, sigma, m, t, delta, sigma_xi=None)
        with_xi = acc.cdp_budget(c, sigma, m, t, delta, sigma_xi=sigma_xi)
        assert with_xi.eps_numerical > base.eps_numerical
        assert with_xi.eps_numerical - base.eps_numerical < 0.05 * base.eps_numerical
        # absolute scale matches Table 1 (~15.26-15.65)
        assert 14.0 < base.eps_numerical < 17.0

    def test_cdp_scaling_in_rounds(self):
        e1 = acc.cdp_budget(1.0, 0.5, 100, 10, 1e-5).eps_numerical
        e2 = acc.cdp_budget(1.0, 0.5, 100, 40, 1e-5).eps_numerical
        # GDP: mu scales with sqrt(T); eps roughly with mu at these scales
        assert 1.5 < e2 / e1 < 3.0

    def test_more_noise_less_eps(self):
        es = [acc.ldp_gaussian_budget(1.0, s, 1e-5).eps_numerical
              for s in (0.5, 1.0, 2.0, 4.0)]
        assert es == sorted(es, reverse=True)
