"""Client-sharded engine equivalence (DESIGN.md §9).

The sharded engine must be the SAME algorithm as the single-device scan
engine for every registered algorithm: per-shard partial sums + one psum may
reorder reductions (allclose, rtol 1e-5), but all randomness — per-client
LDP noise and PrivUnit keys (global-index fold_in), post-reduction CDP noise
and xi (replicated round key), adaptive-clip bit noise — is derived
identically, and on meshes where the reduction order is unchanged many
algorithms stay bit-exact.

These tests run on however many devices the process sees: 1 locally (the
mesh still exercises shard_map + psum + padding), 8 under the CI leg that
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before pytest.
The realization-level LDP equivalence assumes the unsharded release
MATERIALIZES its noise, which backend="auto" guarantees off-TPU (this suite
runs on CPU); on TPU the auto path draws in-kernel noise from a different
stream and the comparison would be distributional only (DESIGN.md §9).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (
    fused_clip_aggregate,
    materialize_ldp_noise,
    partial_clip_moments,
)
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import EngineSpec, FederatedSession, ShardSpec, TrainSpec
from repro.fedsim.local import pad_cohort
from repro.kernels.dp_aggregate.ops import dp_aggregate, dp_aggregate_sums
from repro.launch.mesh import auto_shard_count, client_shard_spec, make_client_mesh

# M deliberately NOT divisible by 8 (nor by 2/4): every multi-device CI leg
# exercises the zero-weight padding path.
M, D, TAU, ETA_L, ROUNDS = 44, 24, 4, 0.1, 6

N_DEV = len(jax.devices())

ALG_KWARGS = {
    "fedavg": {},
    "fedexp": {},
    "dp-fedavg-ldp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "ldp-fedexp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "dp-fedavg-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "ldp-fedexp-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.05),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
}


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data, jnp.zeros(D)


@pytest.fixture(scope="module")
def mesh():
    return make_client_mesh()


def _run(problem, name, *, mesh=None, rounds=ROUNDS):
    data, w0 = problem
    alg = make_algorithm(name, **ALG_KWARGS[name])
    session = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                               train=TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L),
                               shard=ShardSpec(mesh=mesh),
                               eval_fn=distance_to_opt(data.w_star))
    return session.run(jax.random.PRNGKey(11))


class TestShardedEquivalence:
    @pytest.mark.parametrize("name", sorted(ALG_KWARGS))
    def test_sharded_matches_single_device(self, problem, mesh, name):
        """Weights and metrics match at rtol 1e-5 (atol floors the ~0
        components).  The eta histories get a looser relative bar: eta is a
        RATIO of reductions (mean_sq / ||cbar||²), so a 1-ULP reduction-order
        difference between the two separately-compiled XLA programs is
        amplified through rounds of eta-scaled feedback — the weights
        themselves demonstrably stay at 1e-5.
        """
        r1 = _run(problem, name)
        r2 = _run(problem, name, mesh=mesh)
        for field in ("final_w", "last_w", "metric_history"):
            np.testing.assert_allclose(
                np.asarray(getattr(r1, field)), np.asarray(getattr(r2, field)),
                rtol=1e-5, atol=1e-5, err_msg=f"{name}.{field}")
        for field in ("eta_history", "eta_naive_history", "eta_target_history"):
            np.testing.assert_allclose(
                np.asarray(getattr(r1, field)), np.asarray(getattr(r2, field)),
                rtol=1e-4, atol=1e-5, err_msg=f"{name}.{field}")

    @pytest.mark.parametrize("name", ["fedavg", "dp-fedavg-cdp"])
    def test_bit_exact_on_unit_mesh(self, problem, name):
        """Where the reduction order is unchanged (one shard, no padding:
        the mask is all-ones and every masked sum is the reference matvec),
        the sharded engine is bit-for-bit the scan engine."""
        if N_DEV != 1:
            pytest.skip("reduction order only preserved on a 1-device mesh")
        r1 = _run(problem, name)
        r2 = _run(problem, name, mesh=make_client_mesh(1))
        np.testing.assert_array_equal(np.asarray(r1.final_w), np.asarray(r2.final_w))
        np.testing.assert_array_equal(np.asarray(r1.eta_history),
                                      np.asarray(r2.eta_history))

    def test_explicit_padding_shards(self, problem):
        """Force a shard count that does NOT divide M on any device count:
        a 1-shard mesh over padded M=44 -> pad to 44 (no-op) vs the raw run is
        covered above; here pad_cohort itself is checked for mask layout."""
        data, _ = problem
        batches, mask = pad_cohort(data.client_batches(), 8)
        m_pad = mask.shape[0]
        assert m_pad % 8 == 0 and m_pad >= M
        assert float(jnp.sum(mask)) == M
        np.testing.assert_array_equal(np.asarray(mask[:M]), 1.0)
        np.testing.assert_array_equal(np.asarray(mask[M:]), 0.0)
        # padded rows replicate client 0, keeping any loss well-behaved
        for k, v in batches.items():
            assert v.shape[0] == m_pad
            np.testing.assert_array_equal(np.asarray(v[M:]),
                                          np.asarray(jnp.broadcast_to(
                                              v[:1], (m_pad - M,) + v.shape[1:])))

    def test_mesh_requires_scan_engine(self, problem, mesh):
        session = FederatedSession(make_algorithm("fedavg"), linreg_loss,
                                   problem[1], problem[0].client_batches(),
                                   train=TrainSpec(rounds=2, tau=1, eta_l=0.1),
                                   engine=EngineSpec(engine="eager"),
                                   shard=ShardSpec(mesh=mesh))
        with pytest.raises(ValueError, match="scan"):
            session.run(jax.random.PRNGKey(0))


class TestShardedBatched:
    def _batched(self, problem, alg, keys, *, mesh=None, eval_fn=None,
                 w0=None, batches=None, rounds=ROUNDS, **kw):
        data, w0_default = problem
        session = FederatedSession(
            alg, linreg_loss, w0 if w0 is not None else w0_default,
            batches if batches is not None else data.client_batches(),
            train=TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L),
            shard=ShardSpec(mesh=mesh), eval_fn=eval_fn)
        return session.run_batched(keys, **kw)

    def test_batched_sharded_matches_batched(self, problem, mesh):
        data, _ = problem
        alg = make_algorithm("ldp-fedexp-gauss", **ALG_KWARGS["ldp-fedexp-gauss"])
        keys = jnp.stack([jax.random.PRNGKey(21), jax.random.PRNGKey(22)])
        ev = distance_to_opt(data.w_star)
        r1 = self._batched(problem, alg, keys, eval_fn=ev)
        r2 = self._batched(problem, alg, keys, mesh=mesh, eval_fn=ev)
        assert r2.final_w.shape == (2, D)
        # vmap may re-batch BLAS reductions: tolerance, not exact
        np.testing.assert_allclose(np.asarray(r1.final_w), np.asarray(r2.final_w),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(r1.eta_history),
                                   np.asarray(r2.eta_history), rtol=1e-4)

    def test_batched_w0_and_data_sharded(self, problem, mesh):
        data, _ = problem
        alg = make_algorithm("fedexp")
        keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
        w0s = jnp.stack([jnp.zeros(D), 0.1 * jnp.ones(D)])
        batches = {k: jnp.stack([v, v]) for k, v in data.client_batches().items()}
        rb = self._batched(problem, alg, keys, mesh=mesh, w0=w0s,
                           batches=batches, rounds=3,
                           batched_w0=True, batched_data=True)
        assert rb.final_w.shape == (2, D)
        assert not np.allclose(np.asarray(rb.final_w[0]), np.asarray(rb.final_w[1]))


class TestMomentPrimitives:
    """The moment API against the stats API it decomposes."""

    def test_partial_moments_match_fused_stats(self):
        u = 2.0 * jax.random.normal(jax.random.PRNGKey(5), (32, 96))
        noise = materialize_ldp_noise(jax.random.PRNGKey(7), 32, 96, 0.4)
        stats = fused_clip_aggregate(u, 0.5, noise, backend="jnp")
        mom = partial_clip_moments(u, 0.5, noise, backend="jnp")
        np.testing.assert_allclose(np.asarray(mom.sum_c / mom.count),
                                   np.asarray(stats.cbar), rtol=1e-6)
        np.testing.assert_allclose(float(mom.sum_sq / mom.count),
                                   float(stats.mean_sq), rtol=1e-6)
        np.testing.assert_allclose(float(mom.sum_sq_clipped / mom.count),
                                   float(stats.mean_sq_clipped), rtol=1e-6)
        assert float(mom.count) == 32.0

    def test_partial_moments_shard_additivity(self):
        """moments(top) + moments(bottom) == moments(all): the psum law."""
        u = jax.random.normal(jax.random.PRNGKey(9), (40, 64))
        whole = partial_clip_moments(u, 0.7, backend="jnp")
        top = partial_clip_moments(u[:20], 0.7, backend="jnp")
        bot = partial_clip_moments(u[20:], 0.7, backend="jnp")
        np.testing.assert_allclose(np.asarray(top.sum_c + bot.sum_c),
                                   np.asarray(whole.sum_c), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(top.sum_sq + bot.sum_sq),
                                   float(whole.sum_sq), rtol=1e-5)
        assert float(top.count + bot.count) == float(whole.count)

    def test_weight_mask_drops_rows(self):
        u = jax.random.normal(jax.random.PRNGKey(11), (24, 32))
        mask = jnp.concatenate([jnp.ones(20), jnp.zeros(4)])
        # poison the padding rows: the mask must keep NaNs out of every sum
        u = u.at[20:].set(jnp.nan)
        mom = partial_clip_moments(u, 0.5, weight_mask=mask, backend="jnp")
        ref = partial_clip_moments(u[:20], 0.5, backend="jnp")
        assert np.all(np.isfinite(np.asarray(mom.sum_c)))
        np.testing.assert_allclose(np.asarray(mom.sum_c), np.asarray(ref.sum_c),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(float(mom.sum_sq), float(ref.sum_sq), rtol=1e-6)
        assert float(mom.count) == 20.0

    def test_row_weights_weight_released_rows(self):
        """row_weights (the weighted-aggregation layer) multiplies each
        RELEASED row and the count — exact weighted-mean moments."""
        u = jax.random.normal(jax.random.PRNGKey(21), (6, 16))
        mask = jnp.asarray([1.0, 1.0, 0.0, 1.0, 0.0, 1.0])
        v = jnp.asarray([2.0, 1.0, 7.0, 0.5, 7.0, 1.0])
        mom = partial_clip_moments(u, 1e9, weight_mask=mask, row_weights=v,
                                   backend="jnp")
        np.testing.assert_allclose(np.asarray(mom.sum_c),
                                   np.asarray((mask * v) @ u), rtol=1e-6)
        np.testing.assert_allclose(
            float(mom.sum_sq),
            float((mask * v) @ jnp.sum(jnp.square(u), axis=-1)), rtol=1e-6)
        assert float(mom.count) == pytest.approx(4.5)

    def test_kernel_sums_match_jnp_sums(self):
        u = jax.random.normal(jax.random.PRNGKey(13), (24, 300))
        noise = 0.3 * jax.random.normal(jax.random.PRNGKey(14), (24, 300))
        s_k, sq_k, sc_k = dp_aggregate_sums(u, 0.4, noise)
        jm = partial_clip_moments(u, 0.4, noise, backend="jnp")
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(jm.sum_c),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(sq_k), float(jm.sum_sq), rtol=2e-5)
        np.testing.assert_allclose(float(sc_k), float(jm.sum_sq_clipped), rtol=2e-5)

    def test_kernel_sums_consistent_with_dp_aggregate(self):
        u = jax.random.normal(jax.random.PRNGKey(15), (16, 128))
        s, sq, sc = dp_aggregate_sums(u, 0.6)
        stats = dp_aggregate(u, 0.6)
        np.testing.assert_allclose(np.asarray(s / 16), np.asarray(stats.cbar),
                                   rtol=1e-6)
        np.testing.assert_allclose(float(sq / 16), float(stats.mean_sq), rtol=1e-6)

    def test_ldp_noise_shard_offset_matches_rows(self):
        """Row r of the full cohort noise == row 0 of a shard starting at r."""
        key = jax.random.PRNGKey(17)
        full = materialize_ldp_noise(key, 12, 64, 0.9)
        shard = materialize_ldp_noise(key, 4, 64, 0.9, start=8)
        np.testing.assert_array_equal(np.asarray(full[8:]), np.asarray(shard))


class TestAutoShardCount:
    def test_caps_at_min_cohort_slice(self):
        """The heuristic never leaves a shard with < 24 clients (the measured
        collapse regime of the committed bench history)."""
        assert auto_shard_count(96, n_devices=8) == 4
        assert auto_shard_count(300, n_devices=8) == 8
        assert auto_shard_count(10, n_devices=8) == 1
        assert auto_shard_count(48, n_devices=2) == 2

    def test_auto_spec_builds_capped_mesh(self):
        spec = client_shard_spec("auto", num_clients=10_000)
        assert spec.mesh.shape["clients"] == min(N_DEV, 10_000 // 24)
        with pytest.raises(ValueError, match="num_clients"):
            client_shard_spec("auto")


class TestE7ShardedPath:
    def test_e7_sharded_rows(self):
        """The benchmark's sharded scaling curve runs and covers every
        power-of-two shard count up to the visible device count."""
        from benchmarks.e7_engine_throughput import _sharded_rows
        key = jax.random.PRNGKey(0)
        targets = jax.random.normal(key, (16, 64))
        rows = _sharded_rows(targets, jnp.zeros(64), key, rounds=3)
        counts = [r[0] for r in rows]
        assert counts == [n for n in (1, 2, 4, 8, 16) if n <= N_DEV]
        assert all(r[1] > 0 for r in rows)


@pytest.mark.skipif(N_DEV < 2, reason="needs >1 device (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
class TestMultiDevice:
    def test_cohort_is_actually_sharded(self, problem, mesh):
        """The compiled sharded program places distinct client slices on
        distinct devices (not a replicated fallback)."""
        n = mesh.shape["clients"]
        assert n == N_DEV > 1
        r = _run(problem, "ldp-fedexp-gauss", mesh=mesh)
        assert np.all(np.isfinite(np.asarray(r.final_w)))
