"""Step-size rules (the paper's core): Eqs. (2)/(3)/(5)/(6)/(7)/(8)."""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mechanisms as mech
from repro.core import stepsize
from repro.core.aggregation import aggregate_stats, fused_clip_aggregate


def _heterogeneous_updates(key, m=256, d=64):
    """Updates with a shared mean + strong per-client spread (eta_target >> 1)."""
    k1, k2 = jax.random.split(key)
    shared = jax.random.normal(k1, (d,)) * 0.1
    spread = jax.random.normal(k2, (m, d))
    return shared[None, :] + spread


class TestRules:
    def test_fedexp_ge_one(self):
        u = _heterogeneous_updates(jax.random.PRNGKey(0))
        s = aggregate_stats(u)
        eta = stepsize.fedexp(s.mean_sq, s.agg_sq)
        assert float(eta) >= 1.0

    def test_fedexp_heterogeneity_drives_eta(self):
        """Diverse updates -> large eta; identical updates -> eta = 1."""
        u = _heterogeneous_updates(jax.random.PRNGKey(1))
        s = aggregate_stats(u)
        assert float(stepsize.fedexp(s.mean_sq, s.agg_sq)) > 5.0

        same = jnp.tile(u[:1], (u.shape[0], 1))
        s2 = aggregate_stats(same)
        assert float(stepsize.fedexp(s2.mean_sq, s2.agg_sq)) == 1.0

    def test_naive_biased_up_corrected_close(self):
        """Fig. 2: naive rule is inflated by d*sigma^2; Eq. (6) tracks target."""
        m, d, sigma, c_clip = 512, 2000, 0.7, 1.0
        u = _heterogeneous_updates(jax.random.PRNGKey(2), m, d)
        # independent key: fold_in(k, 1) aliases split(k)[1], which would
        # correlate the noise with the spread and bias the cross term.
        noise = sigma * jax.random.normal(jax.random.PRNGKey(9002), (m, d))
        stats = fused_clip_aggregate(u, c_clip, noise)

        eta_naive = float(stepsize.naive_noisy(stats.mean_sq, stats.agg_sq))
        eta_corr = float(stepsize.ldp_gaussian(stats.mean_sq, stats.agg_sq, d, sigma))
        eta_target = float(stepsize.target(stats.mean_sq_clipped, stats.agg_sq))

        # naive >> target (bias d*sigma^2 ~ 980 vs ||Delta||^2 <= 1)
        assert eta_naive > 10 * max(eta_target, 1.0)
        # the corrected NUMERATOR is an unbiased estimate of mean||Delta||^2:
        # |(mean||c||^2 - d sigma^2) - mean||Delta||^2| = O(sqrt(d/M) sigma^2)
        num_corr = float(stats.mean_sq) - d * sigma**2
        num_true = float(stats.mean_sq_clipped)
        assert abs(num_corr - num_true) < 5.0 * np.sqrt(d / m) * sigma**2
        # and the rule clamps at 1 when the target is below 1 (Eq. 6)
        expected = max(1.0, num_corr / float(stats.agg_sq))
        assert abs(eta_corr - expected) < 1e-4 * max(1.0, expected)

    def test_ldp_gaussian_clamps_at_one(self):
        # heavily over-corrected numerator -> max{1, negative} = 1
        eta = stepsize.ldp_gaussian(jnp.float32(1.0), jnp.float32(1.0), 1000, 10.0)
        assert float(eta) == 1.0

    def test_cdp_rule_matches_target_when_xi_zero(self):
        u = _heterogeneous_updates(jax.random.PRNGKey(3))
        stats = fused_clip_aggregate(u, 1.0, None)
        eta = stepsize.cdp(stats.mean_sq_clipped, jnp.float32(0.0), stats.agg_sq)
        want = max(1.0, float(stats.mean_sq_clipped / stats.agg_sq))
        assert float(eta) == np.float32(want)

    def test_privunit_rule(self):
        """Eq. (7) numerator from Algorithm-4 estimates tracks the target."""
        m, d, c_clip = 256, 64, 1.0
        pu = mech.make_privunit_params(d, 2.0, 2.0)
        sc = mech.make_scalardp_params(2.0, c_clip)
        u = _heterogeneous_updates(jax.random.PRNGKey(4), m, d)
        norms = jnp.linalg.norm(u, axis=-1)
        clipped = u * jnp.minimum(1.0, c_clip / norms)[:, None]
        keys = jax.random.split(jax.random.PRNGKey(5), m)
        released = jax.vmap(lambda k, x: mech.privunit_randomize(k, x, pu, sc))(keys, clipped)
        s_hat = jax.vmap(lambda c: mech.estimate_norm_sq(c, pu, sc))(released)
        stats = aggregate_stats(released)
        eta = float(stepsize.ldp_privunit(jnp.mean(s_hat), stats.agg_sq))
        eta_target = float(stepsize.target(
            jnp.mean(jnp.sum(clipped**2, -1)), stats.agg_sq))
        assert eta >= 1.0
        assert abs(eta - eta_target) / eta_target < 0.6


class TestAdaptivity:
    def test_eta_grows_with_m(self):
        """Remark 3.1: effective noise d*sigma^2/M shrinks with M -> eta grows."""
        d, sigma = 500, 0.7
        etas = []
        for m in (16, 128, 1024):
            u = _heterogeneous_updates(jax.random.PRNGKey(7), m, d) * 0.05
            noise = sigma * jax.random.normal(jax.random.PRNGKey(8), (m, d))
            stats = fused_clip_aggregate(u, 1.0, noise)
            etas.append(float(stepsize.ldp_gaussian(stats.mean_sq, stats.agg_sq, d, sigma)))
        assert etas[0] <= etas[1] <= etas[2]
        assert etas[2] > 2.0
