"""Property tests for the §16 compression primitives (hypothesis).

Three properties the example-based suite cannot pin as sharply:

* Rand-k unbiasedness: ``E[decompress(compress(x))] = x`` reduces, by
  linearity, to every coordinate's inclusion frequency being k/d — the
  estimator is ``x_i * (d/k) * 1[i in S]``, so the plan DISTRIBUTION is
  the whole proof obligation.  Checked over a fixed derandomized key
  stream (deterministic — no statistical flake), together with the
  structural half: exactly k distinct in-range indices for every key.
* Sketch additivity, bit-for-bit: on integer-valued float inputs the
  sign-multiply is exact and both sides scatter-add buckets in the same
  j-order, so ``sketch(a) + sketch(b) == sketch(a + b)`` with NO
  tolerance — the §12 additive-moment invariant at its strictest.
* Zero-row masking: a mask-zeroed row contributes exactly zero to the
  compressed moments (``compress(0) == 0`` by linearity), so padding
  clients stay invisible under compression, bit-for-bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.aggregation import partial_clip_moments  # noqa: E402
from repro.core.compression import (  # noqa: E402
    randk_compress,
    randk_decompress,
    randk_plan,
    sketch_compress,
    sketch_plan,
)

MAX_EXAMPLES = 20


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(st.sampled_from([(8, 2), (8, 4), (12, 3), (16, 16), (10, 4), (24, 8)]),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_randk_plan_is_k_distinct_in_range(dk, seed):
    """Every key yields exactly min(k, d) DISTINCT indices in [0, d)."""
    d, k = dk
    idx = np.asarray(randk_plan(jax.random.PRNGKey(seed), d, k))
    assert idx.shape == (min(k, d),)
    assert len(np.unique(idx)) == min(k, d)
    assert idx.min() >= 0 and idx.max() < d


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(8, 2), (8, 4), (12, 3), (10, 4)]))
def test_randk_inclusion_frequency_is_k_over_d(dk):
    """The unbiasedness core: P(i in S) = k/d for EVERY coordinate, both on
    the stratified (k | d) and the permutation-fallback draw.  Frequencies
    are measured over a fixed derandomized key stream, so the tolerance is
    a deterministic bound, not a flaky statistical one."""
    d, k = dk
    n = 600
    counts = np.zeros(d)
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    idx_all = jax.vmap(lambda kk: randk_plan(kk, d, k))(keys)
    for row in np.asarray(idx_all):
        counts[row] += 1
    freq = counts / n
    np.testing.assert_allclose(freq, k / d, atol=0.08)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_randk_roundtrip_is_unbiased_per_plan(seed):
    """For any FIXED plan, decompress(compress(x)) equals (d/k)·x on the
    selected support and 0 elsewhere — the per-plan identity from which
    unbiasedness follows given the k/d inclusion marginal."""
    d, k = 12, 3
    x = np.arange(1.0, d + 1.0, dtype=np.float32)
    idx = randk_plan(jax.random.PRNGKey(seed), d, k)
    est = np.asarray(randk_decompress(randk_compress(jnp.asarray(x), idx),
                                      idx, d))
    expected = np.zeros(d, np.float32)
    expected[np.asarray(idx)] = x[np.asarray(idx)] * (d / k)
    np.testing.assert_array_equal(est, expected)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.lists(st.integers(min_value=-8, max_value=8),
                min_size=24, max_size=24),
       st.lists(st.integers(min_value=-8, max_value=8),
                min_size=24, max_size=24))
def test_sketch_additivity_bit_for_bit(seed, a_ints, b_ints):
    """sketch(a) + sketch(b) == sketch(a + b), EXACTLY, on integer-valued
    floats: the Rademacher multiply is exact and the bucket scatter-adds
    accumulate small integers without rounding."""
    d, width, depth = 12, 5, 3
    a = jnp.asarray(np.asarray(a_ints[:d], np.float32))
    b = jnp.asarray(np.asarray(b_ints[:d], np.float32))
    plan = sketch_plan(jax.random.PRNGKey(seed), d, width, depth)
    lhs = np.asarray(sketch_compress(a, plan, width)
                     + sketch_compress(b, plan, width))
    rhs = np.asarray(sketch_compress(a + b, plan, width))
    np.testing.assert_array_equal(lhs, rhs)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.lists(st.booleans(), min_size=10, max_size=10))
def test_zero_row_masking_compressed(seed, keep):
    """Mask-zeroed rows contribute EXACTLY zero to compressed moments: the
    masked reduction over all rows equals the unmasked reduction over the
    kept rows alone (appending zero rows only re-associates the sum of
    exact zeros, so the equality is bitwise)."""
    if not any(keep):
        keep = keep[:-1] + [True]
    m, d, k = len(keep), 12, 4
    rng = np.random.default_rng(seed % 2**32)
    u = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    mask = jnp.asarray(np.asarray(keep, np.float32))
    idx = randk_plan(jax.random.PRNGKey(seed), d, k)
    compress = lambda x: randk_compress(x, idx)  # noqa: E731

    masked = partial_clip_moments(u, 0.5, weight_mask=mask,
                                  compress_fn=compress)
    kept_rows = u[np.asarray(keep, bool)]
    kept = partial_clip_moments(kept_rows, 0.5, compress_fn=compress)

    np.testing.assert_allclose(np.asarray(masked.sum_c),
                               np.asarray(kept.sum_c), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(masked.sum_sq_clipped),
                               float(kept.sum_sq_clipped), rtol=1e-6)
    assert float(masked.count) == float(jnp.sum(mask))

    # a poisoned masked row must not leak through the compressed sum
    u_poisoned = u.at[np.argmin(np.asarray(keep))].set(jnp.nan) \
        if not all(keep) else u
    poisoned = partial_clip_moments(u_poisoned, 0.5, weight_mask=mask,
                                    compress_fn=compress)
    assert np.all(np.isfinite(np.asarray(poisoned.sum_c)))
