"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import csv
import json
import os
import time

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/bench")


def write_json(name: str, obj) -> str:
    """Dump a benchmark result object to results/bench/<name> (trajectory
    tracking; every benchmark emits one when run.py is passed --json)."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=float)
    return path


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def print_table(title: str, header: list[str], rows: list[list]) -> None:
    print(f"\n== {title} ==")
    widths = [max(len(str(h)), *(len(_fmt(r[i])) for r in rows)) if rows else len(str(h))
              for i, h in enumerate(header)]
    print("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        print("  ".join(_fmt(c).ljust(w) for c, w in zip(r, widths)))


def _fmt(x) -> str:
    if isinstance(x, float):
        if x == 0 or (1e-3 < abs(x) < 1e5):
            return f"{x:.4g}"
        return f"{x:.3e}"
    return str(x)


def mean_std(vals: list[float]) -> tuple[float, float]:
    a = np.asarray(vals, np.float64)
    return float(a.mean()), float(a.std())


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0


def make_dp_algorithm(setting: str, alg: str, *, clip: float, clients: int,
                      dim: int):
    """Setting -> algorithm factory shared by e1/e2 (the paper's protocol:
    sigma = 5C/sqrt(M) for CDP, 0.7C for LDP Gaussian, eps0=eps1=eps2=2 for
    PrivUnit); ``alg`` is "fedexp" or "fedavg"."""
    import math as _math

    from repro.core.fedexp import make_algorithm

    if setting == "cdp":
        name = "cdp-fedexp" if alg == "fedexp" else "dp-fedavg-cdp"
        return make_algorithm(name, clip_norm=clip,
                              sigma=5 * clip / _math.sqrt(clients),
                              num_clients=clients)
    if setting == "ldp-gauss":
        name = "ldp-fedexp-gauss" if alg == "fedexp" else "dp-fedavg-ldp-gauss"
        return make_algorithm(name, clip_norm=clip, sigma=0.7 * clip)
    if setting == "ldp-privunit":
        name = "ldp-fedexp-privunit" if alg == "fedexp" else "dp-fedavg-privunit"
        return make_algorithm(name, clip_norm=clip, eps0=2.0, eps1=2.0,
                              eps2=2.0, dim=dim)
    raise ValueError(f"unknown DP setting {setting!r}")
