"""E7 — engine throughput: scan-compiled round engine vs per-round dispatch.

The headline perf metric from this benchmark onward is ROUNDS PER SECOND of
the simulation hot path.  Three comparisons (DESIGN.md §8):

  1. Engine: the chunked-scan engine (one compiled program for T rounds,
     cross-call program cache) vs the legacy per-round-dispatch loop (one
     jitted program per round, re-traced on every run — exactly how the
     seed-state benchmark suite drove it).  Probed with ``fedavg``
     (minimal server math, so ENGINE overhead dominates — this is the
     headline speedup) and ``fedexp`` / ``ldp-fedexp-gauss`` as
     compute-heavier references.
  2. Aggregation backends at (M, d): tuned-jnp vs Pallas kernel
     (materialized noise) vs Pallas kernel with in-kernel noise, wall-clock
     plus MODELED HBM bytes per round — the bytes model counts (M, d)-array
     traffic: the 3-pass jnp composition reads the update matrix three times
     and writes+reads the noise matrix (5·M·d·4 B); the fused kernel streams
     updates and noise once each plus the noise write (3·M·d·4 B); the
     fused-noise kernel reads the update matrix once, full stop (1·M·d·4 B).
  3. Multi-seed batching: S seeds as one vmapped program vs S sequential
     engine runs, in aggregate rounds/sec.
  4. Client sharding (DESIGN.md §9): the shard_map engine's rounds/sec over a
     1..n_devices ``clients``-mesh scaling curve.  On a stock CPU run there is
     one device and the curve is a single point; CI's 8-device leg
     (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) records the
     full curve.  Forced host devices SHARE the physical cores, so this curve
     measures sharding overhead (shard_map + psum vs one fused program), not
     speedup — real scaling needs real chips; the point is that the overhead
     stays modest and the curve exists to regress against.
  5. Cohort sampling (DESIGN.md §10): rounds/sec of a CohortSpec(q=0.25)
     sampled session vs full participation at the same geometry; the ratio
     (sampling overhead: mask draw + masked moments, never a retrace) is
     gated by ``check_regression.py`` like the other machine-relative
     metrics.
  6. Local SGD (DESIGN.md §11): rounds/sec of a LocalSpec(batch_size,
     epochs) minibatch-client session vs full-batch GD running the SAME
     number of local steps on the same per-sample data — the pytree-native
     LocalTrainer layer through the compiled scan engine.  The gated ratio
     isolates per-step minibatch overhead (shuffle + gather) against
     equally many (cheaper, b-sample) full-set gradient steps; with the
     per-step gradient over b of n samples, the ratio typically lands > 1
     (the committed baseline records ~1.2) and the gate catches
     engine-level regressions of the minibatch path, not local-math cost.
  7. Streaming cohort engine (DESIGN.md §12): a LARGE-M workload (50k
     clients — beyond what the paper experiments stage densely) run with
     engine="stream" at a fixed ``chunk_clients``, vs the dense scan engine
     on the identical geometry.  Streaming trades one fused (M, d) sweep
     for ceil(M/c) sequential chunk steps, so its r/s ratio to dense is the
     per-chunk loop overhead the regression gate watches; the report also
     records the MODELED peak update-matrix bytes — chunk_clients*d*4 for
     the stream engine vs M*d*4 dense, the O(M·d) → O(c·d) memory model
     that makes cohorts bigger than device memory feasible at all.

  8. Fault injection (DESIGN.md §13): rounds/sec of a faulty round (30%
     dropout + 20% stragglers + 2% corrupted updates through the
     masked-moment fault path) vs the clean engine at the same geometry —
     the cheap fault-injection smoke workload; the ratio is gated and the
     faulty run's final params are checked finite.

  9. Telemetry (DESIGN.md §15): a timed CDP run streaming per-round JSONL
     through ``run(tracker=JsonlTracker(...))`` — the engine tap rides the
     compiled program, so this r/s number IS the tracker-on throughput.
     The stream is cross-checked in-process: exactly T lines, and the
     final cumulative-ledger epsilon must equal ``session.privacy_report``
     to 1e-9 (``telemetry.ledger_matches_report``).

 10. Noise schedule (DESIGN.md §17): rounds/sec of a decaying-sigma
     DP-FedEXP run (``cdp-fedexp-schedule``, sigma(t) = sigma0 * decay^t
     threaded through the scan carry's round index) vs the fixed-sigma twin
     at the same geometry, interleaved like the other paired workloads.
     The wrapper's per-round work is one scalar power + a
     ``dataclasses.replace`` resolved at trace time, so the gated ratio
     pins that round-indexed noise stays engine-cost-free; the section also
     records the final distance to the optimum for both runs (the
     decaying schedule should never be wildly worse on this quadratic).

Each comparison is a named WORKLOAD; ``--only <workload> ...`` (also
``main(only=[...])``) runs a subset, and the emitted BENCH_engine.json then
carries only the sections that ran plus a ``partial`` marker —
``check_regression.py`` gates whatever metrics are present.

The sharded scaling curve records ``auto_shards`` — the shard count the
``auto_shard_count`` heuristic would pick for this geometry (it caps shards
so each holds >= a minimum cohort slice, avoiding the 8-shard collapse this
file's history captured).

Emits ``results/bench/BENCH_engine.json`` and a repo-root copy
``BENCH_engine.json`` so the perf trajectory is tracked across PRs
(``benchmarks/check_regression.py`` gates CI on it).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import RESULTS_DIR, print_table, write_csv
from benchmarks.harness import bench_best as _bench
from benchmarks.harness import interleaved_best as _interleaved_best
from benchmarks.harness import timed_rounds
from repro.core.aggregation import fused_clip_aggregate
from repro.core.fedexp import make_algorithm
from repro.fedsim import (
    CohortSpec,
    EngineSpec,
    FaultSpec,
    FederatedSession,
    LocalSpec,
    StreamSpec,
    TrainSpec,
)
from repro.launch.mesh import auto_shard_count, client_shard_spec
from repro.telemetry import JsonlTracker

FLOAT_BYTES = 4

# --only selects a subset of these; the emitted BENCH_engine.json then only
# carries the sections that ran and check_regression gates what is present
WORKLOADS = ("engine", "backends", "sharded", "sampled", "local", "stream",
             "faults", "schedule", "telemetry")


def _quad_loss(w, b):
    """Per-client quadratic pull toward a private target: the cheapest
    possible local objective, so round time is engine + aggregation."""
    return 0.5 * jnp.sum(jnp.square(w - b))


def _telemetry_section(targets, w0, key, rounds):
    """Stream per-round §15 telemetry from a timed private run.

    Runs a CDP session with a ``JsonlTracker`` through the shared
    ``timed_rounds`` harness (the tap is PART of the measured program) and
    cross-checks the stream: exactly ``rounds`` lines, and the final
    cumulative-ledger entry must match ``session.privacy_report`` to 1e-9 —
    the live ledger and the end-of-run accounting are the same composition.
    """
    m = targets.shape[0]
    alg = make_algorithm("dp-fedavg-cdp", clip_norm=0.3,
                         sigma=5 * 0.3 / (m ** 0.5), num_clients=m)
    session = FederatedSession(alg, _quad_loss, w0, targets,
                               train=TrainSpec(rounds=rounds, tau=1,
                                               eta_l=0.5),
                               cohort=CohortSpec(q=0.25))
    path = os.path.join(RESULTS_DIR, "telemetry_e7.jsonl")
    # factory: every pass streams, only the final pass's file survives
    rps, _ = timed_rounds(session, key, rounds,
                          tracker=lambda: JsonlTracker(path))
    with open(path) as f:
        lines = [json.loads(line) for line in f]
    report = session.privacy_report(delta=1e-5)
    ledger_err = abs(lines[-1]["eps"] - report.eps_numerical)
    return {
        "rounds_per_sec": rps,
        "algorithm": "dp-fedavg-cdp",
        "jsonl": path,
        "lines": len(lines),
        "final_ledger_eps": lines[-1]["eps"],
        "privacy_report_eps": report.eps_numerical,
        "ledger_matches_report": bool(len(lines) == rounds
                                      and ledger_err < 1e-9),
    }


def _engine_rows(targets, w0, key, rounds, seeds, algs):
    """Per algorithm: the S-seed evaluation workload (what e1/e2 run) on the
    new engine (ONE vmapped scan program) vs the legacy engine (seeds
    sequential, one jitted program per round, re-traced per call — exactly
    how the seed-state suite drove it), plus the single-seed engines."""
    rows = []
    keys = jnp.stack([jax.random.fold_in(key, 10_000 + s) for s in range(seeds)])
    train = TrainSpec(rounds=rounds, tau=1, eta_l=0.5)
    for name, kw in algs:
        alg = make_algorithm(name, **kw)
        # one session per engine spec: the session owns its compile cache
        sessions = {
            u: FederatedSession(alg, _quad_loss, w0, targets, train=train,
                                engine=EngineSpec(scan_unroll=u))
            for u in (1, 2)}
        eager = FederatedSession(alg, _quad_loss, w0, targets, train=train,
                                 engine=EngineSpec(engine="eager"))

        def batched_run():
            r = sessions[2].run_batched(keys)
            return (r.last_w, r.eta_history)

        def scan_run(unroll):
            r = sessions[unroll].run(key)
            return (r.last_w, r.eta_history)

        def eager_run(n_seeds):
            outs = []
            for s in range(n_seeds):
                # fresh per-call jit, dispatched per round: the legacy cost
                outs.append(eager.run(keys[s]).last_w)
            jax.block_until_ready(outs)
            return outs

        # warm every path first (compile), then INTERLEAVE the timed passes:
        # this box's effective speed swings between measurement windows
        # (shared vCPUs), and interleaving keeps each comparison in-regime
        jax.block_until_ready(batched_run())
        for u in (1, 2):
            jax.block_until_ready(scan_run(u))
        eager_run(1)
        batched_s = scan_s = eager_s = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(batched_run())
            batched_s = min(batched_s, time.perf_counter() - t0)
            # the engine's unroll knob is auto-tuned over {1, 2} per config
            for u in (1, 2):
                t0 = time.perf_counter()
                jax.block_until_ready(scan_run(u))
                scan_s = min(scan_s, time.perf_counter() - t0)
            t0 = time.perf_counter()
            eager_run(1)
            eager_s = min(eager_s, time.perf_counter() - t0)
        rows.append([name,
                     seeds * rounds / batched_s,          # workload r/s, new
                     rounds / scan_s,                     # 1-seed scan r/s
                     rounds / eager_s,                    # 1-seed eager r/s
                     (eager_s * seeds) / batched_s,       # workload speedup
                     eager_s / scan_s])                   # single-seed speedup
    return rows


def _sharded_rows(targets, w0, key, rounds, *, algorithm="ldp-fedexp-gauss",
                  alg_kwargs=(("clip_norm", 0.3), ("sigma", 0.21))):
    """Rounds/sec of the client-sharded engine over 1..n_devices shards.

    Uses the DP probe (clip + per-client noise + step size) so the sharded
    path covers the full moment pipeline, not just the raw mean.
    """
    alg = make_algorithm(algorithm, **dict(alg_kwargs))
    n_dev = len(jax.devices())
    counts = [n for n in (1, 2, 4, 8, 16) if n <= n_dev]
    rows = []
    train = TrainSpec(rounds=rounds, tau=1, eta_l=0.5)
    for n in counts:
        session = FederatedSession(alg, _quad_loss, w0, targets, train=train,
                                   shard=client_shard_spec(n))

        def sharded_run():
            r = session.run(key)
            return (r.last_w, r.eta_history)

        secs = _bench(sharded_run, repeats=3, warm=True)
        rows.append([n, rounds / secs])
    return rows


def _sampled_rows(targets, w0, key, rounds, *, q=0.25,
                  algorithm="ldp-fedexp-gauss",
                  alg_kwargs=(("clip_norm", 0.3), ("sigma", 0.21))):
    """Rounds/sec of the sampled-cohort engine (CohortSpec(q)) vs the full-
    participation engine at the same geometry.

    Sampling adds mask-draw + masked-moment work but never retraces (the mask
    lives inside the scan body), so the overhead should be a small constant
    factor; the ratio is the machine-relative number the regression gate
    watches.
    """
    alg = make_algorithm(algorithm, **dict(alg_kwargs))
    train = TrainSpec(rounds=rounds, tau=1, eta_l=0.5)
    cases = [("full", CohortSpec()), (f"q={q}", CohortSpec(q=q))]
    sessions = [FederatedSession(alg, _quad_loss, w0, targets, train=train,
                                 cohort=cohort) for _, cohort in cases]
    best = _interleaved_best(sessions, key)
    return [[label, rounds / secs]
            for (label, _), secs in zip(cases, best)]


def _local_sgd_rows(key, rounds, *, clients, dim, n_samples=32, batch=8,
                    epochs=1, algorithm="ldp-fedexp-gauss",
                    alg_kwargs=(("clip_norm", 0.3), ("sigma", 0.21))):
    """Rounds/sec of minibatch local SGD (LocalSpec) vs full-batch GD clients
    on the same per-sample data — the e7 probe of the LocalTrainer layer.

    Clients hold (n_samples, dim) targets and the loss means over samples, so
    the minibatch trainer has a real sample axis to shuffle.  Same interleaved
    timing as ``_sampled_rows``: the RATIO is the gated metric.
    """
    alg = make_algorithm(algorithm, **dict(alg_kwargs))
    targets = jax.random.normal(jax.random.fold_in(key, 7),
                                (clients, n_samples, dim))
    w0 = jnp.zeros(dim)

    def sample_loss(w, b):
        return 0.5 * jnp.mean(jnp.sum(jnp.square(w - b), -1))

    # the full-batch comparator runs the SAME number of local steps the
    # minibatch trainer takes (epochs * n/b), so the gated ratio isolates
    # minibatch overhead (per-step shuffle + gather), not extra local math
    steps = epochs * (n_samples // batch)
    train = TrainSpec(rounds=rounds, tau=steps, eta_l=0.5)
    cases = [(f"full-batch tau={steps}", LocalSpec()),
             (f"b={batch} e={epochs}", LocalSpec(batch_size=batch, epochs=epochs))]
    sessions = [FederatedSession(alg, sample_loss, w0, targets, train=train,
                                 local=spec) for _, spec in cases]
    best = _interleaved_best(sessions, key)
    return [[label, rounds / secs]
            for (label, _), secs in zip(cases, best)]


def _stream_rows(key, rounds, *, clients, dim, chunk_clients,
                 algorithm="ldp-fedexp-gauss",
                 alg_kwargs=(("clip_norm", 0.3), ("sigma", 0.21))):
    """Rounds/sec of the streaming cohort engine at large M vs the dense
    scan engine on the same geometry (DESIGN.md §12).

    M is deliberately past the paper-experiment scale (the ROADMAP
    north-star is millions of clients): the streamed session's peak
    update-matrix footprint is chunk_clients*d floats regardless of M, the
    dense comparator stages all M rows.  Same interleaved timing as the
    other paired workloads — the r/s RATIO (inner-chunk-loop overhead) is
    the machine-relative number the regression gate watches.
    """
    alg = make_algorithm(algorithm, **dict(alg_kwargs))
    targets = jax.random.normal(jax.random.fold_in(key, 9), (clients, dim))
    w0 = jnp.zeros(dim)
    train = TrainSpec(rounds=rounds, tau=1, eta_l=0.5)
    cases = [
        ("dense", {}),
        (f"stream c={chunk_clients}",
         dict(engine=EngineSpec(engine="stream"),
              stream=StreamSpec(chunk_clients=chunk_clients))),
    ]
    sessions = [FederatedSession(alg, _quad_loss, w0, targets, train=train,
                                 **kw) for _, kw in cases]
    best = _interleaved_best(sessions, key)
    return [[label, rounds / secs]
            for (label, _), secs in zip(cases, best)]


def _fault_rows(targets, w0, key, rounds, *, algorithm="ldp-fedexp-gauss",
                alg_kwargs=(("clip_norm", 0.3), ("sigma", 0.21))):
    """Rounds/sec of a faulty round (30% dropout + 20% stragglers + 2%
    corrupted updates, DESIGN.md §13) vs the clean engine on the same
    geometry — the cheap fault-injection smoke workload.

    The masked-moment fault path adds a per-round fault draw, straggler step
    resolution and the server-side finite screen, all inside the compiled
    scan body (never a retrace), so the overhead should be a small constant
    factor; the ratio is the machine-relative number the regression gate
    watches.  The faulty run's final params are also checked finite — a
    throughput number from a NaN-poisoned run would be meaningless.
    """
    alg = make_algorithm(algorithm, **dict(alg_kwargs))
    train = TrainSpec(rounds=rounds, tau=3, eta_l=0.2)
    fault = FaultSpec(dropout=0.3, straggler=0.2, straggler_steps=1,
                      corrupt=0.02)
    cases = [("clean", FaultSpec()), ("d=0.3 s=0.2 c=0.02", fault)]
    sessions = [FederatedSession(alg, _quad_loss, w0, targets, train=train,
                                 fault=f) for _, f in cases]
    best = _interleaved_best(sessions, key)
    finite = bool(jnp.all(jnp.isfinite(sessions[1].run(key).last_w)))
    return ([[label, rounds / secs]
             for (label, _), secs in zip(cases, best)], finite)


def _schedule_rows(targets, w0, key, rounds, *, clients, decay=0.95):
    """Rounds/sec of the §17 decaying-sigma engine vs its fixed-sigma twin.

    ``cdp-fedexp-schedule`` threads the round index through the scan carry
    and resolves sigma(t) = sigma0 * decay^t per round; the fixed-sigma
    comparator is the identical composition minus the wrapper.  Interleaved
    timing like the other paired workloads — the RATIO is the gated metric
    (the wrapper should be engine-cost-free).  Also returns the final
    distance to the quadratic optimum (the cohort-mean target) for both
    runs: the decaying schedule spends the same rounds under shrinking
    noise, so a wildly worse final iterate means the schedule is broken,
    not just slow.
    """
    sigma0 = 5 * 0.3 / clients ** 0.5
    kw = dict(clip_norm=0.3, sigma=sigma0, num_clients=clients)
    cases = [("fixed sigma", make_algorithm("cdp-fedexp", **kw)),
             (f"decay={decay}",
              make_algorithm("cdp-fedexp-schedule", decay=decay, **kw))]
    train = TrainSpec(rounds=rounds, tau=1, eta_l=0.5)
    sessions = [FederatedSession(alg, _quad_loss, w0, targets, train=train)
                for _, alg in cases]
    best = _interleaved_best(sessions, key)
    opt = jnp.mean(targets, axis=0)
    dists = [float(jnp.linalg.norm(s.run(key).final_w - opt))
             for s in sessions]
    rows = [[label, rounds / secs, dist]
            for (label, _), secs, dist in zip(cases, best, dists)]
    return rows, sigma0


def _backend_rows(m, d, key):
    u = jax.random.normal(key, (m, d))
    noise = 0.21 * jax.random.normal(jax.random.fold_in(key, 1), (m, d))
    cases = [
        ("jnp_materialized", lambda: fused_clip_aggregate(
            u, 0.3, noise, backend="jnp").cbar, 5 * m * d * FLOAT_BYTES),
        ("kernel_materialized", lambda: fused_clip_aggregate(
            u, 0.3, noise, backend="kernel").cbar, 3 * m * d * FLOAT_BYTES),
        ("kernel_fused_noise", lambda: fused_clip_aggregate(
            u, 0.3, noise_key=key, noise_sigma=0.21,
            backend="kernel-fused").cbar, 1 * m * d * FLOAT_BYTES),
    ]
    rows = []
    for name, fn, model_bytes in cases:
        secs = _bench(fn, repeats=3, warm=True)
        rows.append([name, 1e3 * secs, model_bytes])
    return rows


def main(*, clients: int = 300, dim: int = 4096, rounds: int = 50,
         seeds: int = 4, quick: bool = False, only=None):
    """Defaults are the acceptance geometry (M=300, d=4096, T=50); --quick
    shrinks everything for CI interpret mode.  ``only`` restricts the run to
    a subset of ``WORKLOADS``; the emitted BENCH_engine.json then carries
    only the sections that ran (plus a ``partial`` marker) and
    ``check_regression.py`` gates the metrics that are present."""
    sel = set(only) if only else set(WORKLOADS)
    unknown = sel - set(WORKLOADS)
    if unknown:
        raise SystemExit(f"unknown e7 workload(s) {sorted(unknown)}; "
                         f"choose from: {' '.join(WORKLOADS)}")
    if quick:
        clients, dim, rounds, seeds = 96, 1024, 12, 2

    key = jax.random.PRNGKey(0)
    targets = jax.random.normal(key, (clients, dim))
    w0 = jnp.zeros(dim)

    report = {
        "config": {"clients": clients, "dim": dim, "rounds": rounds,
                   "seeds": seeds, "quick": quick,
                   "backend": jax.default_backend(),
                   # device/CPU counts are part of the config identity:
                   # absolute rounds/sec from a different forced-host-device
                   # leg or machine class are not comparable, and
                   # check_regression gates only the machine-relative
                   # speedup ratios when the configs differ
                   "devices": len(jax.devices()),
                   "host_cpus": os.cpu_count(),
                   # the shard count auto_shard_count picks for this
                   # geometry (satellite of the 8-shard collapse fix)
                   "auto_shards": auto_shard_count(clients)},
    }
    # workload selection stays OUT of the config identity: a partial rerun at
    # the full geometry should still gate its absolute numbers against the
    # committed full baseline
    if sel != set(WORKLOADS):
        report["partial"] = sorted(set(WORKLOADS) - sel)

    engine_rows = None
    if "engine" in sel:
        engine_rows = _engine_rows(targets, w0, key, rounds, seeds, [
            ("fedavg", {}),
            ("fedexp", {}),
            ("ldp-fedexp-gauss", dict(clip_norm=0.3, sigma=0.21)),
        ])
        print_table(
            f"E7 engine throughput (M={clients}, d={dim}, T={rounds}, S={seeds})",
            ["algorithm", "batched r/s", "scan-1 r/s", "eager r/s",
             "workload speedup", "1-seed speedup"], engine_rows)
        write_csv("e7_engine_throughput.csv",
                  ["algorithm", "batched_rps", "scan_rps", "eager_rps",
                   "workload_speedup", "single_seed_speedup"], engine_rows)
        # headline: the better of the two non-private engine probes (fedavg /
        # fedexp) — both isolate engine overhead; taking the max de-noises the
        # shared-vCPU timing swings that hit one measurement window or the other
        headline = max(engine_rows[:2], key=lambda r: r[4])
        report["rounds_per_sec"] = {
            "scan_batched_workload": headline[1],
            "scan_single_seed": headline[2],
            "eager_dispatch": headline[3],
            "per_algorithm": {r[0]: {"batched": r[1], "scan": r[2],
                                     "eager": r[3], "workload_speedup": r[4],
                                     "single_seed_speedup": r[5]}
                              for r in engine_rows},
        }
        # headline: the S-seed evaluation workload (what e1/e2 actually run)
        # on the vmapped scan engine vs seeds-sequential per-round dispatch
        report["speedup_scan_vs_eager"] = headline[4]
        report["speedup_single_seed"] = headline[5]

    if "backends" in sel:
        backend_rows = _backend_rows(clients, dim, key)
        print_table(f"E7 aggregation backends (M={clients}, d={dim})",
                    ["backend", "ms/round", "modeled HBM bytes/round"],
                    backend_rows)
        bytes_by = {r[0]: r[2] for r in backend_rows}
        report["hbm_bytes_per_round_model"] = bytes_by
        report["fused_noise_fewer_bytes_than_materialized"] = (
            bytes_by["kernel_fused_noise"] < bytes_by["kernel_materialized"]
            < bytes_by["jnp_materialized"])
        report["backend_ms_per_round"] = {r[0]: r[1] for r in backend_rows}

    if "sharded" in sel:
        sharded_rows = _sharded_rows(targets, w0, key, rounds)
        print_table(f"E7 client-sharded engine (M={clients}, d={dim}, "
                    f"{len(jax.devices())} devices)",
                    ["client shards", "rounds/sec"], sharded_rows)
        # rounds/sec of the shard_map engine per client-shard count; forced
        # host devices share cores, so this tracks sharding OVERHEAD (see
        # module docstring), keyed by device count for apples-to-apples
        # regression comparisons
        report["sharded"] = {
            "devices": len(jax.devices()),
            "algorithm": "ldp-fedexp-gauss",
            "auto_shards": auto_shard_count(clients),
            "rounds_per_sec_by_shards": {str(r[0]): r[1]
                                         for r in sharded_rows},
        }

    if "sampled" in sel:
        sampled_rows = _sampled_rows(targets, w0, key, rounds)
        print_table(f"E7 sampled-cohort engine (M={clients}, d={dim})",
                    ["cohort", "rounds/sec"], sampled_rows)
        # sampled-cohort workload (CohortSpec(q=0.25) vs full participation,
        # same geometry): relative_to_full is the machine-relative sampling
        # overhead check_regression always gates; absolute r/s gates only on
        # config-matched runs like every other absolute metric
        report["sampled_cohort"] = {
            "q": 0.25,
            "algorithm": "ldp-fedexp-gauss",
            "rounds_per_sec": sampled_rows[1][1],
            "rounds_per_sec_full": sampled_rows[0][1],
            "relative_to_full": sampled_rows[1][1] / sampled_rows[0][1],
        }

    if "local" in sel:
        local_batch, local_epochs, local_samples = 8, 1, 32
        local_rows = _local_sgd_rows(key, rounds, clients=clients,
                                     dim=min(dim, 1024),
                                     n_samples=local_samples,
                                     batch=local_batch, epochs=local_epochs)
        print_table(f"E7 local-SGD clients (M={clients}, d={min(dim, 1024)}, "
                    f"n={local_samples})",
                    ["local trainer", "rounds/sec"], local_rows)
        # minibatch LocalSpec clients vs full-batch GD at the same geometry
        # (DESIGN.md §11): the ratio is machine-relative and always gated
        report["local_sgd"] = {
            "batch_size": local_batch,
            "epochs": local_epochs,
            "n_samples": local_samples,
            "algorithm": "ldp-fedexp-gauss",
            "rounds_per_sec": local_rows[1][1],
            "rounds_per_sec_fullbatch": local_rows[0][1],
            "relative_to_full": local_rows[1][1] / local_rows[0][1],
        }

    if "stream" in sel:
        # large-M streaming workload: M stays >= 50k even in --quick (the
        # whole point is cohort-size scalability); d and T shrink instead
        s_clients, s_dim, s_chunk = 50_000, 64, 2048
        s_rounds = 5 if quick else 10
        stream_rows = _stream_rows(key, s_rounds, clients=s_clients,
                                   dim=s_dim, chunk_clients=s_chunk)
        print_table(f"E7 streaming cohort engine (M={s_clients}, d={s_dim}, "
                    f"T={s_rounds})",
                    ["engine", "rounds/sec"], stream_rows)
        # streaming cohort engine at M >= 50k (DESIGN.md §12): the
        # machine-relative ratio to the dense engine is always gated;
        # peak_update_matrix_bytes is the O(c*d) memory model — the dense
        # comparator stages dense_update_matrix_bytes = M*d*4 instead
        report["streaming"] = {
            "clients": s_clients,
            "dim": s_dim,
            "chunk_clients": s_chunk,
            "rounds": s_rounds,
            "algorithm": "ldp-fedexp-gauss",
            "rounds_per_sec": stream_rows[1][1],
            "rounds_per_sec_dense": stream_rows[0][1],
            "relative_to_dense": stream_rows[1][1] / stream_rows[0][1],
            "peak_update_matrix_bytes": s_chunk * s_dim * FLOAT_BYTES,
            "dense_update_matrix_bytes": s_clients * s_dim * FLOAT_BYTES,
            "memory_reduction_x": s_clients / s_chunk,
        }

    if "faults" in sel:
        fault_rows, fault_finite = _fault_rows(targets, w0, key, rounds)
        print_table(f"E7 fault-injection engine (M={clients}, d={dim})",
                    ["round", "rounds/sec"], fault_rows)
        # faulty round (DESIGN.md §13) vs clean engine: relative_to_clean is
        # the machine-relative fault-path overhead the regression gate
        # watches; final_params_finite pins graceful degradation
        report["faults"] = {
            "dropout": 0.3,
            "straggler": 0.2,
            "corrupt": 0.02,
            "algorithm": "ldp-fedexp-gauss",
            "rounds_per_sec": fault_rows[1][1],
            "rounds_per_sec_clean": fault_rows[0][1],
            "relative_to_clean": fault_rows[1][1] / fault_rows[0][1],
            "final_params_finite": fault_finite,
        }

    if "schedule" in sel:
        schedule_rows, schedule_sigma0 = _schedule_rows(
            targets, w0, key, rounds, clients=clients)
        print_table(f"E7 noise-schedule engine (M={clients}, d={dim})",
                    ["noise", "rounds/sec", "final ||w - w*||"],
                    schedule_rows)
        # decaying-sigma wrapper (DESIGN.md §17) vs fixed sigma:
        # relative_to_fixed is the machine-relative wrapper overhead the
        # regression gate always watches; the final-distance pair pins that
        # the schedule still converges on the quadratic probe
        report["noise_schedule"] = {
            "decay": 0.95,
            "sigma0": schedule_sigma0,
            "algorithm": "cdp-fedexp-schedule",
            "rounds_per_sec": schedule_rows[1][1],
            "rounds_per_sec_fixed": schedule_rows[0][1],
            "relative_to_fixed": schedule_rows[1][1] / schedule_rows[0][1],
            "final_dist": schedule_rows[1][2],
            "final_dist_fixed": schedule_rows[0][2],
            "final_dist_within_2x_fixed": bool(
                schedule_rows[1][2] <= 2.0 * schedule_rows[0][2] + 1e-6),
        }

    os.makedirs(RESULTS_DIR, exist_ok=True)
    if "telemetry" in sel:
        report["telemetry"] = _telemetry_section(targets, w0, key, rounds)

    for path in (os.path.join(RESULTS_DIR, "BENCH_engine.json"),
                 "BENCH_engine.json"):
        with open(path, "w") as f:
            json.dump(report, f, indent=2)

    if "engine" in sel:
        tag = "OK " if report["speedup_scan_vs_eager"] >= 5.0 else "WARN"
        print(f"{tag} scan engine {report['speedup_scan_vs_eager']:.1f}x over the "
              f"per-round-dispatch loop on the {seeds}-seed workload "
              f"({report['speedup_single_seed']:.1f}x single-seed)")
    if "backends" in sel:
        print(f"OK  fused-noise kernel models {bytes_by['kernel_fused_noise']/2**20:.1f} MiB/round "
              f"vs {bytes_by['jnp_materialized']/2**20:.1f} MiB (jnp 3-pass + materialized noise)")
    if "sharded" in sel:
        shard_rps = {r[0]: r[1] for r in sharded_rows}
        top = max(shard_rps)
        print(f"OK  client-sharded engine: {shard_rps[1]:.0f} r/s on a 1-shard mesh, "
              f"{shard_rps[top]:.0f} r/s on {top} shard(s) "
              f"({len(jax.devices())} visible devices)")
    if "sampled" in sel:
        sc = report["sampled_cohort"]
        print(f"OK  sampled-cohort engine (q={sc['q']}): {sc['rounds_per_sec']:.0f} r/s "
              f"vs {sc['rounds_per_sec_full']:.0f} r/s full participation "
              f"({sc['relative_to_full']:.2f}x)")
    if "local" in sel:
        ls = report["local_sgd"]
        print(f"OK  local-SGD clients (b={ls['batch_size']}, e={ls['epochs']}): "
              f"{ls['rounds_per_sec']:.0f} r/s vs {ls['rounds_per_sec_fullbatch']:.0f} "
              f"r/s full-batch ({ls['relative_to_full']:.2f}x); auto shard pick "
              f"for M={clients}: {report['config']['auto_shards']}")
    if "stream" in sel:
        st = report["streaming"]
        print(f"OK  streaming engine (M={st['clients']}, c={st['chunk_clients']}): "
              f"{st['rounds_per_sec']:.1f} r/s vs {st['rounds_per_sec_dense']:.1f} "
              f"r/s dense ({st['relative_to_dense']:.2f}x); peak update matrix "
              f"{st['peak_update_matrix_bytes']/2**20:.1f} MiB vs "
              f"{st['dense_update_matrix_bytes']/2**20:.1f} MiB dense "
              f"({st['memory_reduction_x']:.0f}x smaller)")
    if "faults" in sel:
        fr = report["faults"]
        status = "OK " if fr["final_params_finite"] else "FAIL"
        print(f"{status} fault-injection engine (d={fr['dropout']}, "
              f"s={fr['straggler']}, c={fr['corrupt']}): "
              f"{fr['rounds_per_sec']:.0f} r/s vs "
              f"{fr['rounds_per_sec_clean']:.0f} r/s clean "
              f"({fr['relative_to_clean']:.2f}x); final params finite: "
              f"{fr['final_params_finite']}")
    if "schedule" in sel:
        ns = report["noise_schedule"]
        status = "OK " if ns["final_dist_within_2x_fixed"] else "WARN"
        print(f"{status} noise-schedule engine (decay={ns['decay']}): "
              f"{ns['rounds_per_sec']:.0f} r/s vs "
              f"{ns['rounds_per_sec_fixed']:.0f} r/s fixed sigma "
              f"({ns['relative_to_fixed']:.2f}x); final dist "
              f"{ns['final_dist']:.3f} vs {ns['final_dist_fixed']:.3f} fixed")
    if "telemetry" in sel:
        tl = report["telemetry"]
        status = "OK " if tl["ledger_matches_report"] else "FAIL"
        print(f"{status} telemetry stream ({tl['lines']} rounds -> "
              f"{tl['jsonl']}): {tl['rounds_per_sec']:.0f} r/s with the tap "
              f"compiled in; final ledger eps={tl['final_ledger_eps']:.4f} "
              f"vs privacy_report {tl['privacy_report_eps']:.4f}")
    return engine_rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", nargs="*", default=None, metavar="WORKLOAD",
                    help=f"subset of: {' '.join(WORKLOADS)}")
    args = ap.parse_args()
    main(quick=args.quick, only=args.only)
