"""E4 — Table 1: privacy budgets of DP-FedEXP vs DP-FedAvg.

Closed-form GDP composition (= the numerical-composition answer for Gaussian
mechanisms) + the paper's RDP bounds, for the exact experimental settings:
sigma = 0.7C (LDP Gaussian), eps0=eps1=eps2=2 (PrivUnit), sigma = 5C/sqrt(M),
sigma_xi = d sigma^2 / M, T=50, M=1000, delta=1e-5.
"""
from __future__ import annotations

import math

from benchmarks.common import print_table, write_csv
from repro.core import accounting as acc

T, M, DELTA = 50, 1000, 1e-5
C = 1.0  # budgets below are scale-free in C for the relative comparison


def main():
    rows = []
    # LDP Gaussian: same guarantee for FedAvg and FedEXP (Prop. 4.1)
    ldp = acc.ldp_gaussian_budget(C, 0.7 * C, DELTA)
    rows.append(["LDP (Gaussian)", ldp.eps_numerical, ldp.eps_numerical, ldp.eps_rdp])
    # LDP PrivUnit: pure eps = 6 for both
    pu = acc.privunit_budget(2.0, 2.0, 2.0)
    rows.append(["LDP (PrivUnit)", pu.eps_numerical, pu.eps_numerical, pu.eps_rdp])
    # CDP: FedAvg vs FedEXP with the hyperparameter-free sigma_xi
    sigma = 5.0 * C / math.sqrt(M)
    for name, d in (("CDP (synthetic, d=500)", 500), ("CDP (MNIST CNN, d=5046)", 5046)):
        sigma_xi = d * sigma**2 / M
        avg = acc.cdp_budget(C, sigma, M, T, DELTA, sigma_xi=None)
        exp = acc.cdp_budget(C, sigma, M, T, DELTA, sigma_xi=sigma_xi)
        rows.append([name, exp.eps_numerical, avg.eps_numerical, exp.eps_rdp])
    write_csv("e4_privacy_table1.csv",
              ["setting", "eps_fedexp", "eps_fedavg", "eps_rdp_bound"], rows)
    print_table("E4 privacy budgets (Table 1), delta=1e-5",
                ["setting", "DP-FedEXP", "DP-FedAvg", "RDP bound"], rows)
    print("paper Table 1: LDP(Gauss) 15.659 | PrivUnit 6 | "
          "CDP synth 15.647 vs 15.258 | CDP MNIST 15.261 vs 15.258")
    return rows


if __name__ == "__main__":
    main()
