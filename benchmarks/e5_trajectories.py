"""E5 — Fig. 3: global step-size trajectories eta_g^(t) over training.

Runs DP-FedEXP on the synthetic problem (both DP settings) and records the
adaptive step size per round. The paper's observation: eta decreases as
training progresses on the synthetic task (speed-up early, noise-robustness
late); MNIST-like stays > 1 throughout.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, write_csv
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import FederatedSession, TrainSpec


def main(*, clients: int = 400, rounds: int = 30):
    rows = []
    curves = []
    settings = []
    # CDP, d=500
    data = make_synthetic_linreg(jax.random.PRNGKey(0), clients, 500)
    alg = make_algorithm("cdp-fedexp", clip_norm=0.3,
                         sigma=5 * 0.3 / math.sqrt(clients), num_clients=clients)
    settings.append(("cdp", data, alg, 0.1))
    # LDP Gaussian, d=100
    data_l = make_synthetic_linreg(jax.random.PRNGKey(0), clients, 100)
    alg_l = make_algorithm("ldp-fedexp-gauss", clip_norm=0.3, sigma=0.7 * 0.3)
    settings.append(("ldp-gauss", data_l, alg_l, 0.3))

    for name, data, alg, eta_l in settings:
        w0 = jnp.zeros(data.dim)
        session = FederatedSession(
            alg, linreg_loss, w0, data.client_batches(),
            train=TrainSpec(rounds=rounds, tau=20, eta_l=eta_l),
            eval_fn=distance_to_opt(data.w_star))
        r = session.run(jax.random.PRNGKey(5))
        etas = [float(x) for x in r.eta_history]
        for t, e in enumerate(etas):
            curves.append([name, t, e])
        early = sum(etas[:5]) / 5
        late = sum(etas[-5:]) / 5
        rows.append([name, early, late, max(etas), min(etas)])
    write_csv("e5_eta_trajectories.csv", ["setting", "round", "eta_g"], curves)
    print_table("E5 eta_g trajectories (Fig. 3)",
                ["setting", "eta first5", "eta last5", "max", "min"], rows)
    for name, early, late, _, mn in rows:
        assert mn >= 1.0, (name, mn)
        direction = "decays" if late <= early else "rises"
        print(f"OK  {name}: eta >= 1 throughout; mean first5 {early:.2f} -> "
              f"last5 {late:.2f} ({direction}; trajectory shape is "
              f"scale-dependent, see EXPERIMENTS.md E5)")
    return rows


if __name__ == "__main__":
    main()
