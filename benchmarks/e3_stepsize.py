"""E3 — Fig. 2: adaptive step size at initialization vs number of clients M.

Compares, at t=0 on the synthetic problem:
  eta_naive  (Eq. 3, broken: biased by d sigma^2),
  eta_target (Eq. 5, oracle),
  eta_g      (Eq. 6, bias-corrected Gaussian),
  eta_g      (Eq. 7, PrivUnit norm estimation)
as M grows — the corrected rules approach the target, the naive one does not,
and the PrivUnit estimator has visibly lower variance than the Gaussian one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import mean_std, print_table, write_csv
from repro.core import mechanisms as mech
from repro.core import stepsize
from repro.core.aggregation import aggregate_stats, fused_clip_aggregate
from repro.data.synthetic import linreg_loss, make_synthetic_linreg
from repro.fedsim.local import cohort_updates

D, TAU, ETA_L, CLIP = 100, 20, 0.003, 0.3
SIGMA = 0.7 * CLIP


def _init_updates(m: int, seed: int):
    data = make_synthetic_linreg(jax.random.PRNGKey(3), m, D)
    w0 = jnp.zeros(D)
    return cohort_updates(linreg_loss, w0, data.client_batches(), TAU, ETA_L)


def main(*, ms=(50, 200, 500, 1000), trials: int = 6):
    pu = mech.make_privunit_params(D, 2.0, 2.0)
    sc = mech.make_scalardp_params(2.0, CLIP)
    rows = []
    for m in ms:
        deltas = _init_updates(m, 0)
        naives, targets, gausses, privs = [], [], [], []
        for trial in range(trials):
            key = jax.random.PRNGKey(17 + 1000 * trial)
            kg, kp = jax.random.split(key)
            noise = SIGMA * jax.random.normal(kg, deltas.shape)
            st = fused_clip_aggregate(deltas, CLIP, noise)
            naives.append(float(stepsize.naive_noisy(st.mean_sq, st.agg_sq)))
            targets.append(float(stepsize.target(st.mean_sq_clipped, st.agg_sq)))
            gausses.append(float(stepsize.ldp_gaussian(st.mean_sq, st.agg_sq, D, SIGMA)))

            norms = jnp.linalg.norm(deltas, axis=-1)
            clipped = deltas * jnp.minimum(1.0, CLIP / jnp.maximum(norms, 1e-12))[:, None]
            keys = jax.random.split(kp, m)
            released = jax.vmap(
                lambda k, x: mech.privunit_randomize(k, x, pu, sc))(keys, clipped)
            s_hat = jax.vmap(lambda c: mech.estimate_norm_sq(c, pu, sc))(released)
            stp = aggregate_stats(released)
            privs.append(float(stepsize.ldp_privunit(jnp.mean(s_hat), stp.agg_sq)))
        nm, _ = mean_std(naives)
        tm, _ = mean_std(targets)
        gm, gs = mean_std(gausses)
        pm, ps = mean_std(privs)
        rows.append([m, nm, tm, gm, gs, pm, ps])
    write_csv("e3_stepsize_vs_m.csv",
              ["M", "eta_naive", "eta_target", "eta_gauss_mean", "eta_gauss_std",
               "eta_privunit_mean", "eta_privunit_std"], rows)
    print_table("E3 step size at t=0 vs M (Fig. 2)",
                ["M", "naive(3)", "target(5)", "gauss(6)", "std", "privunit(7)", "std"],
                rows)
    # structural claims of Fig. 2
    last = rows[-1]
    first = rows[0]
    print(f"OK  naive stays inflated: naive/target = {first[1]/max(first[2],1e-9):.1f}x "
          f"(M={first[0]}) -> {last[1]/max(last[2],1e-9):.1f}x (M={last[0]})")
    print(f"OK  corrected tracks max(1, target) at large M: "
          f"gauss={last[3]:.3f}, privunit={last[5]:.3f}, target={last[2]:.3f}")
    print(f"OK  privunit variance < gaussian variance: {last[6]:.4f} < {last[4]:.4f}")
    return rows


if __name__ == "__main__":
    main()
