"""Benchmark regression gate: fresh e7 numbers vs the committed baseline.

    PYTHONPATH=src python benchmarks/check_regression.py          # after a bench run
    make bench-check                                              # bench-quick + gate

Compares the rounds/sec headline metrics of a fresh ``BENCH_engine.json``
(written by ``make bench-quick`` / ``benchmarks.run --only e7``; ``e8`` and
``e9`` MERGE their ``sparse_cohort`` / ``host_resident`` / ``compression``
sections into the same file) against the committed baseline and exits
non-zero when any gated metric regressed by more than ``--threshold``
(default 30%).

Because ``bench-quick`` OVERWRITES the repo-root ``BENCH_engine.json``, the
baseline defaults to ``git show HEAD:BENCH_engine.json`` — the file as
committed — with ``--baseline PATH`` as the escape hatch for detached
checkouts.  Gated metrics are the engine-relative throughputs; the absolute
rounds/sec are also compared but only when the fresh run's config matches
the baseline's — and the config identity includes the device count and host
CPU count precisely so a baseline measured on one machine class never gates
absolute numbers on another (a slower runner would fail spuriously).

Ratio metrics are machine-relative and always gated, but their REGIME still
shifts across machine classes (the committed 8-shard-collapse history is
itself such a shift: per-device slice size flipped the sharding ratio), so
on a config mismatch the ratio threshold relaxes to 2x the configured one —
strict within a machine class, tolerant across classes, never ungated.

A PARTIAL fresh run (``e7 --only <workload> ...``) emits only the sections
that ran; the gate checks whatever metrics are present in both files and
SKIPs the rest, so a targeted single-workload rerun can still be gated
without re-benching everything.

The committed baseline should be refreshed (copy a CI artifact or rerun
``make bench-quick`` on the reference box) whenever a PR intentionally
changes engine throughput.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys

# always gated: dimensionless, machine-relative speedups (the sampled-cohort
# and local-SGD ratios gate per-feature engine overhead: each workload's r/s
# relative to its plain full-participation / full-batch twin)
RATIO_KEYS = (
    ("speedup_scan_vs_eager",),
    ("speedup_single_seed",),
    ("sampled_cohort", "relative_to_full"),
    ("local_sgd", "relative_to_full"),
    ("streaming", "relative_to_dense"),
    ("faults", "relative_to_clean"),
    # e8 §14: sparse gather vs dense sampled at q=1e-3 — the acceptance
    # headline (>= 5x by construction; the gate watches for erosion)
    ("sparse_cohort", "relative_to_dense"),
    # e9 §16: rand-k vs dense rounds/sec, and the modeled bytes reduction
    # (deterministic in (d, k) but gated so a silent comm_floats regression
    # — e.g. a compressor that stops shrinking the payload — fails loudly)
    ("compression", "randk_relative_to_dense"),
    ("compression", "bytes_reduction_randk"),
    # e7 §17: decaying-sigma wrapper vs fixed sigma — the wrapper resolves
    # sigma(t) at trace time, so its throughput ratio should sit at ~1.0;
    # erosion means round-indexed noise grew real per-round cost
    ("noise_schedule", "relative_to_fixed"),
)
# gated only when the run configs match: absolute throughputs
ABS_KEYS = (
    ("rounds_per_sec", "scan_batched_workload"),
    ("rounds_per_sec", "scan_single_seed"),
    ("sampled_cohort", "rounds_per_sec"),
    ("local_sgd", "rounds_per_sec"),
    ("streaming", "rounds_per_sec"),
    ("faults", "rounds_per_sec"),
    ("sparse_cohort", "rounds_per_sec"),
    ("host_resident", "rounds_per_sec"),
    ("compression", "rounds_per_sec"),
    ("noise_schedule", "rounds_per_sec"),
)


def _get(d, path):
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def _load_baseline(path: str | None):
    if path is not None:
        with open(path) as f:
            return json.load(f), path
    try:
        blob = subprocess.run(
            ["git", "show", "HEAD:BENCH_engine.json"],
            capture_output=True, text=True, check=True).stdout
        return json.loads(blob), "git:HEAD:BENCH_engine.json"
    except (subprocess.CalledProcessError, FileNotFoundError, json.JSONDecodeError):
        return None, None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="BENCH_engine.json",
                    help="freshly-benchmarked JSON (default: repo root copy)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: HEAD's committed copy)")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max tolerated fractional regression (default 0.30)")
    args = ap.parse_args(argv)

    try:
        with open(args.fresh) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL cannot read fresh benchmark {args.fresh!r}: {e}")
        return 2

    base, base_src = _load_baseline(args.baseline)
    if base is None:
        print("SKIP no committed BENCH_engine.json baseline found; "
              "gate passes vacuously (first benchmarked commit)")
        return 0

    # e8/e9 merge their sections + "e8_config"/"e9_config" into e7's file;
    # every identity present must match before absolute numbers gate (the
    # auto-resolved chunk size is part of e8_config — an auto pick that
    # moves is a config change; e9_config pins the compression geometry)
    mismatched = [k for k in ("config", "e8_config", "e9_config")
                  if base.get(k) != fresh.get(k)]
    configs_match = not mismatched
    ratio_threshold = args.threshold if configs_match else 2.0 * args.threshold
    checks = [(".".join(k), _get(base, k), _get(fresh, k))
              for k in (list(RATIO_KEYS)
                        + (list(ABS_KEYS) if configs_match else []))]
    if not configs_match:
        print(f"NOTE {' + '.join(mismatched)} mismatch vs baseline "
              f"({[base.get(k) for k in mismatched]} != "
              f"{[fresh.get(k) for k in mismatched]}); gating ratio metrics "
              f"only, at the relaxed cross-machine-class threshold "
              f"-{ratio_threshold:.0%}")
    # a partial run (e7 --only <workload>) emits only the sections that ran;
    # the missing metrics SKIP below rather than failing the gate
    if fresh.get("partial"):
        print(f"NOTE partial fresh run (workloads not run: "
              f"{', '.join(fresh['partial'])}); gating present metrics only")

    failed = []
    gated = 0
    for name, b, f in checks:
        if b is None or f is None or not isinstance(b, (int, float)) or b <= 0:
            print(f"SKIP {name}: missing/invalid in baseline or fresh run")
            continue
        gated += 1
        is_ratio = tuple(name.split(".")) in RATIO_KEYS
        threshold = ratio_threshold if is_ratio else args.threshold
        drop = (b - f) / b
        status = "FAIL" if drop > threshold else "ok  "
        print(f"{status} {name}: baseline {b:.2f} -> fresh {f:.2f} "
              f"({-drop:+.1%} vs -{threshold:.0%} floor)")
        if drop > threshold:
            failed.append(name)

    if failed:
        print(f"FAIL benchmark regression gate ({base_src}): {', '.join(failed)} "
              f"regressed more than {args.threshold:.0%}")
        return 1
    if gated == 0:
        print("OK  benchmark regression gate passed vacuously (no metric "
              "present in both baseline and fresh run — partial run against "
              "an older baseline?)")
        return 0
    print(f"OK  benchmark regression gate passed ({gated} metric(s) gated)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
