"""E2 — Fig. 1 (right) + Table 4: image classification with the paper's CNNs.

Offline substitute for MNIST (generated 28x28 10-class set, DESIGN.md §7),
Dirichlet(0.3) split over M clients (Hsu et al.), tau=10 local steps, T=50
rounds. CDP uses the 2-conv+2-FC CNN (d=5046), LDP the small CNN (d=237).
Metric: test accuracy averaged over the last 5 rounds (Table 4 protocol).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import make_dp_algorithm, mean_std, print_table, write_csv
from repro.data.dirichlet import client_image_batches, dirichlet_partition
from repro.data.images import make_image_dataset
from repro.fedsim import FederatedSession, LocalSpec, TrainSpec
from repro.fedsim.scaffold import DPScaffoldConfig, run_dp_scaffold
from repro.models.cnn import (
    accuracy_fn,
    make_cnn,
    make_cnn_params,
    masked_xent_loss,
    pytree_accuracy_fn,
    pytree_xent_loss,
)

# (eta_l, C): LDP rows follow the paper's Table 2; the CDP row is re-selected
# on OUR generated dataset (micro-grid, see EXPERIMENTS.md) — the paper's
# CDP pick (0.1, 0.3) under-clips here and loses ~25 points for both algs.
HP = {
    "ldp-gauss": {"fedexp": (0.03, 0.1), "fedavg": (0.03, 0.3), "scaffold": (0.1, 0.1)},
    "ldp-privunit": {"fedexp": (0.03, 0.3), "fedavg": (0.03, 0.3), "scaffold": (0.03, 0.1)},
    "cdp": {"fedexp": (0.1, 1.0), "fedavg": (0.1, 1.0), "scaffold": (0.1, 0.3)},
}


def _make_problem(setting: str, clients: int, seed: int, dataset=None):
    if dataset is None:  # seed-independent; callers hoist it across seeds
        dataset = make_image_dataset(jax.random.PRNGKey(7))
    part = dirichlet_partition(seed, jax.device_get(dataset.train_y), clients, alpha=0.3)
    batches = client_image_batches(dataset, part)
    model = make_cnn(jax.random.PRNGKey(100 + seed), "cdp" if setting == "cdp" else "ldp")
    loss = masked_xent_loss(model)
    eval_fn = accuracy_fn(model, dataset.test_x, dataset.test_y)
    return model, loss, eval_fn, batches


def _make_e2_algorithm(setting: str, alg: str, clients: int, dim: int):
    _, c = HP[setting][alg]
    return make_dp_algorithm(setting, alg, clip=c, clients=clients, dim=dim)


def _run(setting, alg, model, loss, eval_fn, batches, *, clients, rounds, tau, seed):
    eta_l, c = HP[setting][alg]
    key = jax.random.PRNGKey(2000 + seed)
    if alg == "scaffold":
        central = setting == "cdp"
        sigma = 5 * c / math.sqrt(clients) if central else 0.7 * c
        cfg = DPScaffoldConfig(clip_norm=c, sigma=sigma, central=central, num_clients=clients)
        return run_dp_scaffold(cfg, loss, model.init_flat, batches, rounds=rounds,
                               tau=tau, eta_l=eta_l, key=key, eval_fn=eval_fn)
    algorithm = _make_e2_algorithm(setting, alg, clients, model.dim)
    session = FederatedSession(algorithm, loss, model.init_flat, batches,
                               train=TrainSpec(rounds=rounds, tau=tau, eta_l=eta_l),
                               eval_fn=eval_fn)
    return session.run(key)


def _run_batched(setting, alg, problems, *, clients, rounds, tau, seeds):
    """All seeds as ONE batched program: per-seed model inits and Dirichlet
    partitions ride a leading seed axis (batched_w0 / batched_data); the
    architecture, loss, and eval closure are shared."""
    model, loss, eval_fn, _ = problems[0]
    eta_l, _c = HP[setting][alg]
    keys = jnp.stack([jax.random.PRNGKey(2000 + s) for s in range(seeds)])
    w0s = jnp.stack([p[0].init_flat for p in problems])
    batches = {k: jnp.stack([p[3][k] for p in problems])
               for k in problems[0][3]}
    algorithm = _make_e2_algorithm(setting, alg, clients, model.dim)
    session = FederatedSession(algorithm, loss, w0s, batches,
                               train=TrainSpec(rounds=rounds, tau=tau, eta_l=eta_l),
                               eval_fn=eval_fn)
    return session.run_batched(keys, batched_w0=True, batched_data=True)


def quick_smoke(*, clients: int = 16, rounds: int = 3, batch_size: int = 8):
    """CI smoke: a real CNN as a raw parameter PYTREE trained with minibatch
    local SGD (LocalSpec) through the compiled scan engine — the CNN/MNIST
    leg of the composable-stack acceptance (DESIGN.md §11).  No flat-vector
    wrapper anywhere in user code; the session ravels at the clip/aggregate
    boundary."""
    import numpy as np

    dataset = make_image_dataset(jax.random.PRNGKey(7), num_train=1600,
                                 num_test=400)
    part = dirichlet_partition(0, jax.device_get(dataset.train_y), clients,
                               alpha=0.3)
    batches = client_image_batches(dataset, part)
    params = make_cnn_params(jax.random.PRNGKey(100), "cdp")
    alg = make_dp_algorithm("cdp", "fedexp", clip=1.0, clients=clients,
                            dim=sum(int(p.size) for p in
                                    jax.tree_util.tree_leaves(params)))
    session = FederatedSession(
        alg, pytree_xent_loss(), params, batches,
        train=TrainSpec(rounds=rounds, tau=1, eta_l=0.1),
        local=LocalSpec(batch_size=batch_size, epochs=1, momentum=0.9),
        eval_fn=pytree_accuracy_fn(dataset.test_x, dataset.test_y))
    r = session.run(jax.random.PRNGKey(0))
    accs = np.asarray(r.metric_history)
    assert isinstance(r.final_w, dict) and r.final_w["c1_w"].shape == (4, 4, 1, 4)
    assert np.all(np.isfinite(accs)), f"non-finite metrics: {accs}"
    rep = session.privacy_report(1e-5)
    print(f"OK  e2 --quick: pytree CNN + minibatch local SGD (b={batch_size}, "
          f"momentum=0.9) through the scan engine; acc trajectory "
          f"{[round(float(a), 3) for a in accs]}")
    print(f"OK  {rep}")
    return accs


def main(*, clients: int = 150, rounds: int = 25, tau: int = 10, seeds: int = 1):
    """Reduced from the paper's M=1000/T=50/5 seeds for the single-core CI
    budget (noise scale keeps the paper's sigma = 5C/sqrt(M) formula).
    Non-scaffold cells run all seeds as one batched scan-engine program."""
    rows, curves = [], []
    dataset = make_image_dataset(jax.random.PRNGKey(7))  # shared by all seeds
    for setting in ("cdp", "ldp-gauss", "ldp-privunit"):
        problems = [_make_problem(setting, clients, s, dataset=dataset)
                    for s in range(seeds)]
        for alg in ("fedavg", "fedexp", "scaffold"):
            accs = []
            if alg == "scaffold":
                for s in range(seeds):
                    model, loss, eval_fn, batches = problems[s]
                    r = _run(setting, alg, model, loss, eval_fn, batches,
                             clients=clients, rounds=rounds, tau=tau, seed=s)
                    hist = [float(x) for x in r.metric_history]
                    accs.append(100.0 * sum(hist[-5:]) / 5.0)  # Table 4 protocol
                    if s == 0:
                        for t, v in enumerate(hist):
                            curves.append([setting, alg, t, 100.0 * v])
            else:
                r = _run_batched(setting, alg, problems, clients=clients,
                                 rounds=rounds, tau=tau, seeds=seeds)
                for s in range(seeds):
                    hist = [float(x) for x in r.metric_history[s]]
                    accs.append(100.0 * sum(hist[-5:]) / 5.0)  # Table 4 protocol
                    if s == 0:
                        for t, v in enumerate(hist):
                            curves.append([setting, alg, t, 100.0 * v])
            mu, sd = mean_std(accs)
            rows.append([setting, alg, mu, sd])
    write_csv("e2_mnistlike_curves.csv", ["setting", "algorithm", "round", "acc"], curves)
    write_csv("e2_mnistlike_table4.csv",
              ["setting", "algorithm", "acc_mean", "acc_std"], rows)
    print_table("E2 MNIST-like CNN: test acc %, mean of last 5 rounds (Table 4)",
                ["setting", "algorithm", "acc", "std"], rows)
    for setting in ("cdp", "ldp-gauss", "ldp-privunit"):
        exp = next(r[2] for r in rows if r[0] == setting and r[1] == "fedexp")
        avg = next(r[2] for r in rows if r[0] == setting and r[1] == "fedavg")
        if max(exp, avg) < 15.0:
            # LDP noise at reduced M swamps the tiny CNN: both algorithms sit
            # at chance — inconclusive, not a win/loss (paper uses M=1000).
            print(f"n/a {setting}: at-chance at reduced M "
                  f"(FedEXP {exp:.2f}% / FedAvg {avg:.2f}%); rerun with "
                  f"clients=1000 for the paper's regime")
            continue
        tag = "OK " if exp >= avg - 0.3 else "WARN"
        print(f"{tag} {setting}: DP-FedEXP {exp:.2f}% vs DP-FedAvg {avg:.2f}%")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CNN-via-pytree minibatch smoke (CI leg)")
    args = ap.parse_args()
    if args.quick:
        quick_smoke()
    else:
        main()
