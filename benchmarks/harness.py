"""Shared benchmark timing harness (factored from e7/e8, DESIGN.md §15).

Three primitives every throughput benchmark in this suite builds on:

* ``bench_best(fn)`` — best-of-N wall clock of a thunk, compile warmed
  first.  The shared-vCPU CI boxes swing between measurement windows, so
  the MIN over repeats is the stable statistic.
* ``interleaved_best(sessions, key)`` — best wall-clock per session with
  the timed passes INTERLEAVED across sessions, keeping paired A/B
  comparisons in the same load regime; the r/s RATIO is the
  machine-relative number ``check_regression.py`` gates.
* ``timed_rounds(session, key, rounds)`` — rounds/sec of one session
  (warm, then best of ``repeats``), returning the last run's outputs so
  callers can sanity-check them.  Pass ``tracker=`` to stream §15
  telemetry from the FINAL (timed) pass — the tap adds an io_callback to
  the compiled program, so telemetry-on timings are reported as their own
  number, never silently mixed into a tracker-off baseline.

All timing uses ``jax.block_until_ready`` on the returned arrays, so
asynchronous dispatch never flatters a measurement.
"""
from __future__ import annotations

import time

import jax

__all__ = ["bench_best", "interleaved_best", "timed_rounds"]


def bench_best(fn, *, repeats: int = 3, warm: bool = True) -> float:
    """Best wall-clock seconds of ``fn()`` over ``repeats`` timed calls."""
    if warm:
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _default_run(session, key):
    r = session.run(key)
    return (r.last_w, r.eta_history)


def interleaved_best(sessions, key, *, repeats: int = 3, run=_default_run):
    """Best wall-clock per session, passes INTERLEAVED across sessions.

    Warms every session first (compile), then takes the min of ``repeats``
    interleaved passes so paired sessions see the same load regime.
    ``run(session, key)`` must return device arrays to block on.
    """
    for s in sessions:
        jax.block_until_ready(run(s, key))
    best = [float("inf")] * len(sessions)
    for _ in range(repeats):
        for i, s in enumerate(sessions):
            t0 = time.perf_counter()
            jax.block_until_ready(run(s, key))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def timed_rounds(session, key, rounds: int, *, repeats: int = 2,
                 tracker=None):
    """(rounds/sec, last RunResult outputs) of ``session.run(key)``.

    With ``tracker``, every pass (warm + timed) streams telemetry — the
    tap is part of the compiled program being measured.  Pass a ZERO-ARG
    FACTORY (e.g. ``lambda: JsonlTracker(path)``) when only the final
    pass's stream should survive: each pass then gets a fresh sink, and an
    overwriting ``JsonlTracker`` leaves exactly the last T-round stream on
    disk.  A plain ``Tracker`` instance is reused across passes and
    observes all of them.
    """
    def one():
        if tracker is None:
            r = session.run(key)
        else:
            r = session.run(key, tracker=tracker() if callable(tracker)
                            else tracker)
        return (r.last_w, r.eta_history)

    jax.block_until_ready(one())          # compile + first staging
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = one()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return rounds / best, out
