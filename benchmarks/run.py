"""Benchmark driver: one benchmark per paper table/figure + perf tracking.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only e3 e4
    PYTHONPATH=src python -m benchmarks.run --quick     # reduced sizes (CI)
    PYTHONPATH=src python -m benchmarks.run --json      # + results/bench/*.json

Benchmarks:
    e1  Fig. 1 left   — synthetic linreg convergence (3 DP settings x 3 algs)
    e2  Fig. 1 right / Table 4 — MNIST-like CNN test accuracy
    e3  Fig. 2        — step-size bias correction vs M
    e4  Table 1       — privacy budgets
    e5  Fig. 3        — eta_g trajectories
    e6  (beyond-paper) FedOpt server-lr sensitivity vs hyperparameter-free
    e7  engine throughput — scan engine vs per-round dispatch; always emits
        BENCH_engine.json (results/bench/ + repo root) for trajectory tracking
    e8  million-client rounds — sparse sampled cohorts + host-resident data
        (DESIGN.md §14); merges its sections into BENCH_engine.json
    e9  compressed communication — rand-k + count-sketch vs dense at d >= 2**20
        (DESIGN.md §16); merges its sections into BENCH_engine.json
    roofline          — §Roofline tables (baseline + optimized) from dry-runs
"""
from __future__ import annotations

import argparse
import time

ALL = ("e1", "e2", "e3", "e4", "e5", "e6", "e7", "e8", "e9", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of: {' '.join(ALL)}")
    ap.add_argument("--quick", action="store_true", help="reduced sizes (CI)")
    ap.add_argument("--json", action="store_true",
                    help="emit results/bench/<name>.json per benchmark")
    args = ap.parse_args()
    which = set(args.only) if args.only else set(ALL)
    if args.quick and not args.only and "e2" in which:
        # the CNN cells compile for ~100 s EACH on a 2-vCPU CI box (seed
        # state was no faster); e2 stays full-run / --only-e2 territory
        which.discard("e2")
        print("skipping e2 under --quick (CNN cells compile ~100 s each; "
              "run with --only e2 to include it)")

    emitted = {}

    def record(name, rows):
        if args.json and rows is not None:
            from benchmarks.common import write_json
            emitted[name] = write_json(f"{name}.json", {"benchmark": name,
                                                        "quick": args.quick,
                                                        "rows": rows})

    t0 = time.time()
    if "e4" in which:  # closed-form, instant
        from benchmarks import e4_privacy
        record("e4_privacy", e4_privacy.main())
    if "e3" in which:
        from benchmarks import e3_stepsize
        if args.quick:
            record("e3_stepsize", e3_stepsize.main(ms=(50, 200, 1000), trials=4))
        else:
            record("e3_stepsize", e3_stepsize.main())
    if "e1" in which:
        from benchmarks import e1_synthetic
        if args.quick:
            record("e1_synthetic", e1_synthetic.main(clients=300, rounds=20, seeds=2))
        else:
            record("e1_synthetic", e1_synthetic.main())
    if "e5" in which:
        from benchmarks import e5_trajectories
        if args.quick:
            record("e5_trajectories", e5_trajectories.main(clients=300, rounds=20))
        else:
            record("e5_trajectories", e5_trajectories.main())
    if "e2" in which:
        from benchmarks import e2_mnist
        if args.quick:
            record("e2_mnist", e2_mnist.main(clients=60, rounds=5, seeds=1))
        else:
            record("e2_mnist", e2_mnist.main())
    if "e6" in which:
        from benchmarks import e6_fedopt_ablation
        if args.quick:
            record("e6_fedopt", e6_fedopt_ablation.main(
                clients=150, dim=80, rounds=10, lr_grid=(0.01, 0.1, 0.3)))
        else:
            record("e6_fedopt", e6_fedopt_ablation.main())
    if "e7" in which:
        from benchmarks import e7_engine_throughput
        record("e7_engine", e7_engine_throughput.main(quick=args.quick))
    if "e8" in which:
        # AFTER e7: e7 overwrites BENCH_engine.json wholesale, e8 merges
        from benchmarks import e8_million_clients
        record("e8_million_clients", e8_million_clients.main(quick=args.quick))
    if "e9" in which:
        # also after e7 (merge, don't overwrite) — see e8 comment above
        from benchmarks import e9_compression
        record("e9_compression", e9_compression.main(quick=args.quick))
    if "roofline" in which:
        import os as _os
        from benchmarks import roofline_table
        if _os.path.isdir("results/dryrun_baseline"):
            _os.environ["REPRO_DRYRUN"] = "results/dryrun_baseline"
            import importlib
            importlib.reload(roofline_table)
            roofline_table.main("16x16", label="paper-faithful-baseline")
            roofline_table.main("2x16x16", label="paper-faithful-baseline")
            _os.environ["REPRO_DRYRUN"] = "results/dryrun"
            importlib.reload(roofline_table)
        roofline_table.main("16x16", label="optimized")
        roofline_table.main("2x16x16", label="optimized")
    if emitted:
        print("json results:", ", ".join(sorted(emitted.values())))
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s; CSVs in results/bench/")


if __name__ == "__main__":
    main()
