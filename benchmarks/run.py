"""Benchmark driver: one benchmark per paper table/figure + the roofline table.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only e3 e4
    PYTHONPATH=src python -m benchmarks.run --quick     # reduced sizes

Benchmarks:
    e1  Fig. 1 left   — synthetic linreg convergence (3 DP settings x 3 algs)
    e2  Fig. 1 right / Table 4 — MNIST-like CNN test accuracy
    e3  Fig. 2        — step-size bias correction vs M
    e4  Table 1       — privacy budgets
    e5  Fig. 3        — eta_g trajectories
    e6  (beyond-paper) FedOpt server-lr sensitivity vs hyperparameter-free
    roofline          — §Roofline tables (baseline + optimized) from dry-runs
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of: e1 e2 e3 e4 e5 roofline")
    ap.add_argument("--quick", action="store_true", help="reduced sizes (CI)")
    args = ap.parse_args()
    which = set(args.only) if args.only else {"e1", "e2", "e3", "e4", "e5", "e6", "roofline"}

    t0 = time.time()
    if "e4" in which:  # closed-form, instant
        from benchmarks import e4_privacy
        e4_privacy.main()
    if "e3" in which:
        from benchmarks import e3_stepsize
        if args.quick:
            e3_stepsize.main(ms=(50, 200, 1000), trials=4)
        else:
            e3_stepsize.main()
    if "e1" in which:
        from benchmarks import e1_synthetic
        if args.quick:
            e1_synthetic.main(clients=300, rounds=20, seeds=2)
        else:
            e1_synthetic.main()
    if "e5" in which:
        from benchmarks import e5_trajectories
        if args.quick:
            e5_trajectories.main(clients=300, rounds=20)
        else:
            e5_trajectories.main()
    if "e2" in which:
        from benchmarks import e2_mnist
        if args.quick:
            e2_mnist.main(clients=100, rounds=10, seeds=1)
        else:
            e2_mnist.main()
    if "e6" in which:
        from benchmarks import e6_fedopt_ablation
        e6_fedopt_ablation.main()
    if "roofline" in which:
        import os as _os
        from benchmarks import roofline_table
        if _os.path.isdir("results/dryrun_baseline"):
            _os.environ["REPRO_DRYRUN"] = "results/dryrun_baseline"
            import importlib
            importlib.reload(roofline_table)
            roofline_table.main("16x16", label="paper-faithful-baseline")
            roofline_table.main("2x16x16", label="paper-faithful-baseline")
            _os.environ["REPRO_DRYRUN"] = "results/dryrun"
            importlib.reload(roofline_table)
        roofline_table.main("16x16", label="optimized")
        roofline_table.main("2x16x16", label="optimized")
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s; CSVs in results/bench/")


if __name__ == "__main__":
    main()
