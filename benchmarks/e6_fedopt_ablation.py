"""E6 (beyond-paper ablation) — hyperparameter sensitivity of DP-FedOpt
vs the hyperparameter-free DP-FedEXP.

The paper's practical argument: FedOpt-style servers (Reddi et al., 2021)
need a global learning rate whose DP-safe tuning is expensive and leaks
privacy (Papernot & Steinke: accounting the tuning can double/triple
epsilon). This ablation quantifies it on the synthetic CDP task:

  - DP-FedAdam across a server-lr grid -> best/worst spread,
  - CDP-FedEXP with NO tuned server hyperparameter, one run,
  - the privacy cost of the grid: K runs on sensitive data compose; even
    with RDP-optimal selection the budget multiplies.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, write_csv
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import FederatedSession, TrainSpec

M, D, ROUNDS, TAU, CLIP, ETA_L = 400, 200, 30, 20, 0.3, 0.1
LR_GRID = (0.003, 0.01, 0.03, 0.1, 0.3)


def main(*, clients: int = M, dim: int = D, rounds: int = ROUNDS,
         lr_grid: tuple = LR_GRID):
    """``clients``/``dim``/``rounds``/``lr_grid`` shrink for --quick CI runs."""
    data = make_synthetic_linreg(jax.random.PRNGKey(0), clients, dim)
    w0 = jnp.zeros(dim)
    ev = distance_to_opt(data.w_star)
    sigma = 5 * CLIP / math.sqrt(clients)

    train = TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L)

    def run(alg):
        return FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                                train=train, eval_fn=ev).run(jax.random.PRNGKey(9))

    rows = []
    for lr in lr_grid:
        r = run(make_algorithm("dp-fedadam-cdp", clip_norm=CLIP, sigma=sigma,
                               num_clients=clients, server_lr=lr))
        rows.append([f"dp-fedadam lr={lr}", float(r.metric_history[-1])])

    r = run(make_algorithm("cdp-fedexp", clip_norm=CLIP, sigma=sigma,
                           num_clients=clients))
    rows.append(["cdp-fedexp (no server hp)", float(r.metric_history[-1])])

    write_csv("e6_fedopt_ablation.csv", ["algorithm", "final_dist"], rows)
    print_table("E6 FedOpt server-lr sensitivity vs hyperparameter-free DP-FedEXP",
                ["algorithm", "final ||w-w*||"], rows)
    adam_vals = [v for n, v in rows if n.startswith("dp-fedadam")]
    fedexp_val = rows[-1][1]
    print(f"OK  adam spread across lr grid: best {min(adam_vals):.3f} / "
          f"worst {max(adam_vals):.3f} ({max(adam_vals)/min(adam_vals):.1f}x)")
    print(f"OK  fedexp (zero tuned server hps): {fedexp_val:.3f} "
          f"vs adam best {min(adam_vals):.3f}")
    print(f"    and the adam grid costs {len(lr_grid)}x the training runs on "
          f"sensitive data — the privacy overhead the paper avoids.")
    return rows


if __name__ == "__main__":
    main()
