"""Roofline table — aggregates the dry-run JSONs into EXPERIMENTS.md §Roofline.

Reads results/dryrun/<arch>__<shape>__<mesh>.json (produced by
``python -m repro.launch.dryrun``) and prints/persists the three roofline
terms, dominant bottleneck, MODEL_FLOPS ratio per (arch x shape) pair.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import print_table, write_csv

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN", "results/dryrun")


def load(mesh: str = "16x16", tag: str = "") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}{tag}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def main(mesh: str = "16x16", label: str = "optimized"):
    recs = load(mesh)
    if not recs:
        print(f"no dry-run records for mesh {mesh} in {DRYRUN_DIR}; "
              f"run: PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all")
        return []
    rows = []
    for r in recs:
        t = r["roofline"]
        total = t["compute_s"] + t["memory_s"] + t["collective_s"]
        rows.append([
            r["arch"], r["shape"],
            t["compute_s"], t["memory_s"], t["collective_s"],
            t["bottleneck"].replace("_s", ""),
            r["useful_ratio"] if r["useful_ratio"] else float("nan"),
            max(t["compute_s"] / total, t["memory_s"] / total,
                t["collective_s"] / total),
        ])
    rows.sort(key=lambda x: (x[0], x[1]))
    write_csv(f"roofline_{label}_{mesh}.csv",
              ["arch", "shape", "compute_s", "memory_s", "collective_s",
               "bottleneck", "useful_flops_ratio", "dominance"], rows)
    print_table(f"Roofline terms per (arch x shape), mesh {mesh} [{label}] (per-chip seconds)",
                ["arch", "shape", "compute", "memory", "collective", "bound",
                 "useful", "dom%"], rows)
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "16x16")
