"""E8 — million-client rounds: sparse sampled cohorts + host-resident data.

The §14 scalability benchmark: M = 10**6 clients as a MEASURED number, not a
memory model.  Two workloads (DESIGN.md §14):

  1. ``sparse`` — a q = 1e-3 Bernoulli-sampled round on M = 10**6 device-
     resident clients, run two ways on identical geometry: the dense sampled
     engine (all M local updates computed, non-participants zero-weighted —
     static shapes, O(M*d) per round) vs ``CohortSpec(gather=True)`` (the
     sampled cohort packed into a dense (cap, ...) block via ``gather_slots``
     and ONLY those rows trained — O(q*M*d) per round).  The gated headline
     is ``sparse_cohort.relative_to_dense``: at q = 1e-3 the gather path must
     beat the dense sampled engine by >= 5x rounds/sec (the acceptance
     floor; in practice it lands orders of magnitude higher).  The dense
     comparator is timed over fewer rounds — at O(M*d) per round it is the
     slow side by construction, and rounds/sec normalizes the comparison.

  2. ``host`` — the same M with NO device-resident copy at all: a
     ``SyntheticSource`` serves client rows from the host on demand, the
     session gathers the sampled cohort's GLOBAL indices and only ever
     fetches ~cap rows per round, double-buffered ``DataSpec.prefetch``
     chunks ahead of the §12 inner scan.  Records rounds/sec (gated as
     ``host_resident.rounds_per_sec``), the MODELED peak update memory
     (chunk_clients*d floats for the update block + the staged batch
     window — the O(c*d) model that bounds M by host storage, not HBM), and
     the MEASURED process peak RSS (``getrusage`` high-watermark; the host
     workload runs first so the watermark is not inflated by the sparse
     workload's deliberate M*d staging).

Both workloads resolve ``StreamSpec(chunk_clients="auto")`` from the live
device memory budget (docs/scaling.md sizing rule) and record the resolved
value in the e8 config identity — an auto pick that lands somewhere new is a
config mismatch, not a silent absolute-number regression.

``--quick`` keeps M >= 10**5 (the CI floor — shrinking M below that would
benchmark nothing this file exists to measure) and shrinks rounds instead.

Unlike e7 (which owns BENCH_engine.json and overwrites it wholesale), e8
MERGES its sections into the existing file — read-modify-write of
``sparse_cohort``, ``host_resident`` and ``e8_config`` — so one committed
baseline carries both benchmarks and ``check_regression.py`` gates whatever
is present.
"""
from __future__ import annotations

import json
import os
import resource

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, print_table
from benchmarks.harness import timed_rounds
from repro.core.fedexp import make_algorithm
from repro.fedsim import (
    CohortSpec,
    DataSpec,
    EngineSpec,
    FederatedSession,
    StreamSpec,
    SyntheticSource,
    TrainSpec,
)

FLOAT_BYTES = 4
WORKLOADS = ("host", "sparse")  # host first: keeps its RSS watermark honest

Q = 1e-3
DIM = 32


def _quad_loss(w, b):
    return 0.5 * jnp.sum(jnp.square(w - b["t"]))


def _make_source(clients: int, dim: int) -> SyntheticSource:
    """Deterministic per-client rows generated on fetch — no M-sized array
    ever exists; the host 'storage' here is a closed form of the index."""
    mix = (np.arange(1, dim + 1, dtype=np.int64) * 2654435761) % (2**31)

    def fetch(idx):
        g = (np.asarray(idx, np.int64)[:, None] + 1) * mix[None, :]
        return {"t": ((g % 2039) / 1019.5 - 1.0).astype(np.float32)}

    return SyntheticSource(fetch, num_clients=clients)


def _time_run(session, key, rounds):
    """Shared warm-then-best-of-2 harness (benchmarks/harness.py)."""
    return timed_rounds(session, key, rounds, repeats=2)


def _merge_report(sections: dict) -> None:
    """Read-modify-write BENCH_engine.json: e7 owns the file and overwrites
    it wholesale, so e8 folds its sections into whatever is committed."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    for path in (os.path.join(RESULTS_DIR, "BENCH_engine.json"),
                 "BENCH_engine.json"):
        try:
            with open(path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError):
            report = {}
        report.update(sections)
        with open(path, "w") as f:
            json.dump(report, f, indent=2)


def main(*, clients: int = 1_000_000, rounds: int = 20, quick: bool = False,
         only=None):
    sel = set(only) if only else set(WORKLOADS)
    unknown = sel - set(WORKLOADS)
    if unknown:
        raise SystemExit(f"unknown e8 workload(s) {sorted(unknown)}; "
                         f"choose from: {' '.join(WORKLOADS)}")
    if quick:
        # the CI floor: M never drops below 1e5 (a small-M run would not
        # exercise the sparse/host machinery this benchmark gates)
        clients, rounds = max(100_000, clients // 10), 6
    dense_rounds = 2 if quick else 3

    key = jax.random.PRNGKey(0)
    w0 = jnp.zeros(DIM)
    cohort_dense = CohortSpec(q=Q)
    cohort_gather = CohortSpec(q=Q, gather=True)
    cap = cohort_gather.resolved_cap(clients)
    sections: dict = {}
    chunk_auto = None

    if "host" in sel:
        train = TrainSpec(rounds=rounds, tau=1, eta_l=0.5)
        source = _make_source(clients, DIM)
        session = FederatedSession(
            make_algorithm("ldp-fedexp-gauss", clip_norm=0.3, sigma=0.21),
            _quad_loss, w0, source, train=train,
            engine=EngineSpec(engine="stream"),
            stream=StreamSpec(chunk_clients="auto"),
            cohort=cohort_gather, data=DataSpec(kind="synthetic", prefetch=2))
        chunk_auto = session.stream.chunk_clients
        c = min(chunk_auto, cap)
        rps, (last_w, _) = _time_run(session, key, rounds)
        peak_rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
        client_bytes = DIM * FLOAT_BYTES
        modeled = (2 * c * DIM * FLOAT_BYTES            # batch + update block
                   + 2 * c * client_bytes)              # double-buffer window
        rows = [["host gather", rps, modeled / 2**20, peak_rss / 2**20]]
        print_table(
            f"E8 host-resident million-client rounds (M={clients}, d={DIM}, "
            f"q={Q}, T={rounds})",
            ["workload", "rounds/sec", "modeled peak MiB", "measured RSS MiB"],
            rows)
        sections["host_resident"] = {
            "clients": clients,
            "dim": DIM,
            "q": Q,
            "rounds": rounds,
            "cap": cap,
            "chunk_clients": chunk_auto,
            "prefetch": 2,
            "algorithm": "ldp-fedexp-gauss",
            "rounds_per_sec": rps,
            "modeled_peak_update_bytes": modeled,
            "measured_peak_rss_bytes": peak_rss,
            "final_params_finite": bool(jnp.all(jnp.isfinite(last_w))),
        }

    if "sparse" in sel:
        # device-resident comparison: stage all M rows once (M*d*4 bytes —
        # the cost the host workload exists to avoid), then time the gather
        # engine vs the dense sampled engine on the identical geometry
        targets = {"t": jax.block_until_ready(
            jax.device_put(_make_source(clients, DIM).fetch(
                np.arange(clients))["t"]))}
        alg = "ldp-fedexp-gauss"

        def session_for(cohort, n_rounds):
            return FederatedSession(
                make_algorithm(alg, clip_norm=0.3, sigma=0.21),
                _quad_loss, w0, targets,
                train=TrainSpec(rounds=n_rounds, tau=1, eta_l=0.5),
                engine=EngineSpec(engine="stream"),
                stream=StreamSpec(chunk_clients="auto"), cohort=cohort)

        sparse_session = session_for(cohort_gather, rounds)
        chunk_auto = sparse_session.stream.chunk_clients
        sparse_rps, (last_w, _) = _time_run(sparse_session, key, rounds)
        dense_rps, _ = _time_run(session_for(cohort_dense, dense_rounds),
                                 key, dense_rounds)
        ratio = sparse_rps / dense_rps
        c = min(chunk_auto, cap)
        rows = [["dense sampled", dense_rps, clients * DIM * FLOAT_BYTES / 2**20],
                ["sparse gather", sparse_rps, c * DIM * FLOAT_BYTES / 2**20]]
        print_table(
            f"E8 sparse sampled cohort (M={clients}, d={DIM}, q={Q})",
            ["engine", "rounds/sec", "peak update MiB"], rows)
        sections["sparse_cohort"] = {
            "clients": clients,
            "dim": DIM,
            "q": Q,
            "rounds": rounds,
            "dense_rounds": dense_rounds,
            "cap": cap,
            "chunk_clients": chunk_auto,
            "algorithm": alg,
            "rounds_per_sec": sparse_rps,
            "rounds_per_sec_dense": dense_rps,
            "relative_to_dense": ratio,
            "peak_update_matrix_bytes": c * DIM * FLOAT_BYTES,
            "dense_update_matrix_bytes": clients * DIM * FLOAT_BYTES,
            "final_params_finite": bool(jnp.all(jnp.isfinite(last_w))),
        }

    # the e8 config identity: check_regression compares it alongside e7's
    # before gating absolute rounds/sec; the auto-resolved chunk is part of
    # it (an auto pick that moves is a config change, not a regression)
    sections["e8_config"] = {
        "clients": clients, "dim": DIM, "q": Q, "rounds": rounds,
        "quick": quick, "chunk_clients_auto": chunk_auto,
        "backend": jax.default_backend(), "devices": len(jax.devices()),
        "host_cpus": os.cpu_count(),
    }
    if sel != set(WORKLOADS):
        sections["e8_partial"] = sorted(set(WORKLOADS) - sel)
    _merge_report(sections)

    ok = True
    if "host" in sel:
        hr = sections["host_resident"]
        print(f"OK  host-resident M={clients}: {hr['rounds_per_sec']:.2f} r/s, "
              f"modeled peak {hr['modeled_peak_update_bytes']/2**20:.1f} MiB, "
              f"measured RSS {hr['measured_peak_rss_bytes']/2**20:.0f} MiB "
              f"(cap={cap}, chunk={hr['chunk_clients']})")
    if "sparse" in sel:
        sc = sections["sparse_cohort"]
        ok = sc["relative_to_dense"] >= 5.0 and sc["final_params_finite"]
        tag = "OK " if ok else "WARN"
        print(f"{tag} sparse gather at q={Q}: {sc['rounds_per_sec']:.2f} r/s vs "
              f"{sc['rounds_per_sec_dense']:.2f} r/s dense sampled "
              f"({sc['relative_to_dense']:.0f}x; acceptance floor 5x); peak "
              f"update matrix {sc['peak_update_matrix_bytes']/2**20:.2f} MiB "
              f"vs {sc['dense_update_matrix_bytes']/2**20:.0f} MiB dense")
    return [[k, v] for k, v in sections.items() if k != "e8_config"]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--clients", type=int, default=1_000_000)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--only", nargs="*", default=None, metavar="WORKLOAD",
                    help=f"subset of: {' '.join(WORKLOADS)}")
    args = ap.parse_args()
    main(clients=args.clients, rounds=args.rounds, quick=args.quick,
         only=args.only)
