"""E9 — §16 compressed communication: rand-k + count-sketch vs dense.

The communication benchmark for the DESIGN.md §16 compression layer at a
paper-scale model dimension: d >= 2**20 as a MEASURED number (the --quick CI
floor — shrinking d below that would benchmark a regime where compression is
pointless).  Three variants of the same cdp-fedexp spec on identical
geometry, timed interleaved so the ratios are machine-relative:

  dense   — the uncompressed baseline: O(d) reduced state per round.
  rand-k  — ``RandKAggregation(k=d//64)``: the round collective carries a
            (k,) coordinate sample; clip-scale commutation means the clipped
            (M, d) matrix is never materialized (~1 O(M*d) pass vs the dense
            path's ~3), which is where the >= 2x rounds/sec headline comes
            from.
  sketch  — ``CountSketchAggregation(width=d//256, depth=3)``: O(width*depth)
            reduced state; the depth scatter-adds cost more compute than
            rand-k, so its headline is bytes, not speed.

Reported per variant: rounds/sec, the MODELED bytes-per-round
(``4 * algorithm.comm_floats(d)`` — the §16 communication model the
telemetry tap streams as ``bytes_per_round``) and the reduction vs dense.

Convergence parity is checked on the LOSSLESS rand-k leg (k = d): it runs
the entire compressed pipeline — per-round plan from the COMPRESS_TAG key,
compressed-domain CDP noise, decompress, FedEXP eta from the uncompressed
scalar moments — while keeping the map invertible, so its loss decrease
must match dense within a few percent (noise realization differs; the math
must not).  The LOSSY legs trade per-round progress for bytes by
construction: with FedEXP's eta >= 1 floor, the unbiased d/k amplification
moves k coordinates per round at dense step size, so equal-ROUND loss
decrease is k/d of dense — their decrease ratios are recorded as
informational fields, not gated (equal-BYTES parity is the regime the
compression literature claims, and it needs d/k more rounds than a CI
benchmark can afford).

When more than one device is visible, a second leg times dense vs rand-k
under ``shard=client_shard_spec(n)``: the §16 point is that the per-round
collective (the psum payload) drops from O(d) to O(k) with NO engine
change, so the sharded ratio is recorded too.

Like e8, e9 MERGES its ``compression`` + ``e9_config`` sections into
BENCH_engine.json (e7 owns the file and overwrites it wholesale), so one
committed baseline carries all three benchmarks and ``check_regression.py``
gates whatever is present.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, print_table
from benchmarks.harness import interleaved_best
from repro.core.compose import (
    CountSketchAggregation,
    RandKAggregation,
    with_compression,
)
from repro.core.fedexp import make_algorithm
from repro.fedsim import FederatedSession, TrainSpec
from repro.launch.mesh import auto_shard_count, client_shard_spec

FLOAT_BYTES = 4
DIM_FLOOR = 1 << 20   # the CI floor: d never drops below 2**20
CLIP = 1.0
# keeps the CDP noise VECTOR norm (sigma/sqrt(M) per coordinate over d
# coordinates) well under the unit-norm signal at d = 2**20 — a paper-scale
# sigma would have every variant random-walking and nothing to compare
SIGMA = 5e-4
K_DIV = 64            # rand-k keeps d/64 coordinates
W_DIV = 256           # sketch width d/256, depth 3
DEPTH = 3


def _quad_loss(w, b):
    return 0.5 * jnp.sum(jnp.square(w - b["t"]))


def _targets(m: int, d: int) -> np.ndarray:
    """(m, d) client targets = shared signal + 30% heterogeneity, both at
    O(1) norm so clip=1 binds the way a trained model's update does.  A
    pure-noise target set has mean ~0 == w0 and nothing to learn."""
    rng = np.random.default_rng(0)
    shared = (rng.standard_normal(d) * d**-0.5).astype(np.float32)
    het = (rng.standard_normal((m, d)) * d**-0.5).astype(np.float32)
    return shared[None, :] + 0.3 * het


def _mean_loss(w, targets: np.ndarray) -> float:
    w = np.asarray(w)
    return float(np.mean(0.5 * np.sum(np.square(w[None, :] - targets), -1)))


def _algorithm(m: int, aggregation=None):
    alg = make_algorithm("cdp-fedexp", clip_norm=CLIP, sigma=SIGMA,
                         num_clients=m)
    return alg if aggregation is None else with_compression(alg, aggregation)


def _merge_report(sections: dict) -> None:
    """Read-modify-write BENCH_engine.json (same discipline as e8: e7 owns
    the file, later benchmarks fold their sections in)."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    for path in (os.path.join(RESULTS_DIR, "BENCH_engine.json"),
                 "BENCH_engine.json"):
        try:
            with open(path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError):
            report = {}
        report.update(sections)
        with open(path, "w") as f:
            json.dump(report, f, indent=2)


def main(*, dim: int = DIM_FLOOR, clients: int = 256, rounds: int = 10,
         quick: bool = False):
    if quick:
        clients, rounds = 64, 4
    dim = max(dim, DIM_FLOOR)
    k = dim // K_DIV
    width = dim // W_DIV

    key = jax.random.PRNGKey(0)
    w0 = jnp.zeros((dim,))
    targets = _targets(clients, dim)
    batches = {"t": jax.device_put(targets)}
    train = TrainSpec(rounds=rounds, tau=1, eta_l=0.5)

    def session(aggregation=None, *, shard=None, n_rounds=rounds):
        kw = {} if shard is None else {"shard": shard}
        return FederatedSession(
            _algorithm(clients, aggregation), _quad_loss, w0, batches,
            train=TrainSpec(rounds=n_rounds, tau=1, eta_l=0.5), **kw)

    variants = [
        ("dense", None),
        (f"rand-k (k=d/{K_DIV})", RandKAggregation(k=k)),
        (f"sketch ({W_DIV}:1 x{DEPTH})",
         CountSketchAggregation(width=width, depth=DEPTH)),
    ]
    sessions = [session(agg) for _, agg in variants]
    bytes_pr = [FLOAT_BYTES * s.algorithm.comm_floats(dim) for s in sessions]
    repeats = 2 if quick else 3
    best = interleaved_best(sessions, key, repeats=repeats)
    rps = [rounds / b for b in best]

    rows = [[name, r, bpr / 2**20, bytes_pr[0] / bpr]
            for (name, _), r, bpr in zip(variants, rps, bytes_pr)]
    print_table(
        f"E9 compressed communication (M={clients}, d={dim}, T={rounds})",
        ["variant", "rounds/sec", "bytes/round MiB", "bytes reduction"],
        rows)

    # convergence: lossless rand-k (k=d) must match dense; lossy decreases
    # are informational (see module docstring)
    parity_rounds = min(rounds, 4)
    L0 = _mean_loss(w0, targets)
    finals = {}
    for tag, agg in [("dense", None), ("lossless", RandKAggregation(k=dim)),
                     ("randk", RandKAggregation(k=k)),
                     ("sketch", CountSketchAggregation(width=width,
                                                       depth=DEPTH))]:
        r = session(agg, n_rounds=parity_rounds).run(key)
        finals[tag] = _mean_loss(r.last_w, targets)
    dense_dec = L0 - finals["dense"]
    parity_err = abs(finals["lossless"] - finals["dense"]) / max(dense_dec,
                                                                 1e-12)
    parity_ok = dense_dec > 0 and parity_err < 0.05

    section = {
        "clients": clients, "dim": dim, "rounds": rounds,
        "k": k, "width": width, "depth": DEPTH,
        "algorithm": "cdp-fedexp",
        "rounds_per_sec": rps[1],                 # the rand-k headline
        "rounds_per_sec_dense": rps[0],
        "rounds_per_sec_sketch": rps[2],
        "randk_relative_to_dense": rps[1] / rps[0],
        "sketch_relative_to_dense": rps[2] / rps[0],
        "bytes_per_round_dense": bytes_pr[0],
        "bytes_per_round_randk": bytes_pr[1],
        "bytes_per_round_sketch": bytes_pr[2],
        "bytes_reduction_randk": bytes_pr[0] / bytes_pr[1],
        "bytes_reduction_sketch": bytes_pr[0] / bytes_pr[2],
        "parity_rounds": parity_rounds,
        "parity_rel_err": parity_err,
        "convergence_parity": bool(parity_ok),
        "lossy_decrease_ratio_randk": (L0 - finals["randk"]) / max(dense_dec,
                                                                   1e-12),
        "lossy_decrease_ratio_sketch": (L0 - finals["sketch"]) / max(dense_dec,
                                                                     1e-12),
        "final_params_finite": bool(all(np.isfinite(v) for v in
                                        finals.values())),
    }

    n_dev = len(jax.devices())
    if n_dev > 1:
        # the sharded leg: the collective payload is the compressed pytree,
        # so the psum itself shrinks from O(d) to O(k) — no engine change
        n = auto_shard_count(clients, n_devices=n_dev)
        sh_sessions = [session(None, shard=client_shard_spec(n)),
                       session(RandKAggregation(k=k),
                               shard=client_shard_spec(n))]
        sh_best = interleaved_best(sh_sessions, key, repeats=repeats)
        sh_rps = [rounds / b for b in sh_best]
        print_table(
            f"E9 sharded leg ({n} client shards)",
            ["variant", "rounds/sec"],
            [["dense", sh_rps[0]], ["rand-k", sh_rps[1]]])
        section["sharded"] = {
            "shards": n, "devices": n_dev,
            "rounds_per_sec_dense": sh_rps[0],
            "rounds_per_sec_randk": sh_rps[1],
            "randk_relative_to_dense": sh_rps[1] / sh_rps[0],
        }

    sections = {
        "compression": section,
        "e9_config": {
            "clients": clients, "dim": dim, "rounds": rounds, "quick": quick,
            "k": k, "width": width, "depth": DEPTH,
            "backend": jax.default_backend(), "devices": n_dev,
            "host_cpus": os.cpu_count(),
        },
    }
    _merge_report(sections)

    speed_ok = section["randk_relative_to_dense"] >= 2.0
    bytes_ok = section["bytes_reduction_randk"] >= 10.0
    tag = "OK " if (speed_ok and bytes_ok and parity_ok) else "WARN"
    print(f"{tag} rand-k k=d/{K_DIV}: "
          f"{section['randk_relative_to_dense']:.2f}x dense rounds/sec "
          f"(floor 2x), {section['bytes_reduction_randk']:.0f}x fewer bytes "
          f"(floor 10x); lossless-leg parity err "
          f"{section['parity_rel_err']:.1%} (floor 5%); lossy equal-round "
          f"decrease {section['lossy_decrease_ratio_randk']:+.3f}x dense "
          f"(informational — progress traded for bytes)")
    return [[key_, val] for key_, val in section.items()]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dim", type=int, default=DIM_FLOOR)
    ap.add_argument("--clients", type=int, default=256)
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()
    main(dim=args.dim, clients=args.clients, rounds=args.rounds,
         quick=args.quick)
