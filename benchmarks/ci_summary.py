"""Emit a GitHub job-summary markdown table from BENCH_engine.json.

    python benchmarks/ci_summary.py >> "$GITHUB_STEP_SUMMARY"

One table of per-algorithm rounds/sec (batched / scan / eager + speedups) and
one line per client-shard count from the sharded scaling curve, so each
(python x device-count) matrix leg publishes its throughput at a glance
without downloading the artifact.  When the e7 telemetry workload ran, its
JSONL stream (``--telemetry-jsonl``, default the path e7 writes) also yields
a round-time line — median/p95 wall-clock per round as measured by the §15
tap, the live-run observability the benchmark exists to exercise.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_engine.json")
    ap.add_argument("--title", default="Engine throughput")
    ap.add_argument("--telemetry-jsonl", default="results/bench/telemetry_e7.jsonl")
    args = ap.parse_args(argv)
    try:
        with open(args.json) as f:
            rep = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"_no benchmark JSON ({e})_")
        return 0

    cfg = rep.get("config", {})
    print(f"### {args.title}")
    print(f"`M={cfg.get('clients')} d={cfg.get('dim')} T={cfg.get('rounds')} "
          f"S={cfg.get('seeds')} backend={cfg.get('backend')} "
          f"quick={cfg.get('quick')}`\n")
    print("| algorithm | batched r/s | scan r/s | eager r/s | workload speedup |")
    print("|---|---:|---:|---:|---:|")
    per_alg = rep.get("rounds_per_sec", {}).get("per_algorithm", {})
    for name, row in per_alg.items():
        print(f"| {name} | {row.get('batched', 0):.0f} | {row.get('scan', 0):.0f} "
              f"| {row.get('eager', 0):.0f} | {row.get('workload_speedup', 0):.1f}x |")

    sharded = rep.get("sharded")
    if sharded:
        print(f"\n**Client-sharded engine** ({sharded.get('devices')} devices, "
              f"{sharded.get('algorithm')}):\n")
        print("| client shards | rounds/sec |")
        print("|---:|---:|")
        for n, rps in sorted(sharded.get("rounds_per_sec_by_shards", {}).items(),
                             key=lambda kv: int(kv[0])):
            print(f"| {n} | {rps:.0f} |")

    st = rep.get("streaming")
    if st:
        print(f"\n**Streaming cohort engine** (M={st.get('clients')}, "
              f"c={st.get('chunk_clients')}): {st.get('rounds_per_sec', 0):.1f} r/s "
              f"vs {st.get('rounds_per_sec_dense', 0):.1f} dense "
              f"({st.get('relative_to_dense', 0):.2f}x), update matrix "
              f"{st.get('memory_reduction_x', 0):.0f}x smaller")

    sc = rep.get("sparse_cohort")
    if sc:
        print(f"\n**Sparse sampled cohort (e8)** (M={sc.get('clients')}, "
              f"q={sc.get('q')}, cap={sc.get('cap')}): "
              f"{sc.get('rounds_per_sec', 0):.1f} r/s vs "
              f"{sc.get('rounds_per_sec_dense', 0):.2f} dense sampled "
              f"({sc.get('relative_to_dense', 0):.0f}x), peak update matrix "
              f"{sc.get('peak_update_matrix_bytes', 0)/2**20:.2f} MiB vs "
              f"{sc.get('dense_update_matrix_bytes', 0)/2**20:.0f} MiB dense")

    hr = rep.get("host_resident")
    if hr:
        print(f"\n**Host-resident clients (e8)** (M={hr.get('clients')}, "
              f"q={hr.get('q')}, chunk={hr.get('chunk_clients')}, "
              f"prefetch={hr.get('prefetch')}): "
              f"{hr.get('rounds_per_sec', 0):.1f} r/s, modeled peak "
              f"{hr.get('modeled_peak_update_bytes', 0)/2**20:.1f} MiB, "
              f"measured RSS {hr.get('measured_peak_rss_bytes', 0)/2**20:.0f} MiB")

    cp = rep.get("compression")
    if cp:
        parity = "parity ok" if cp.get("convergence_parity") else "PARITY FAIL"
        line = (f"\n**Compressed communication (e9)** (M={cp.get('clients')}, "
                f"d={cp.get('dim')}, k={cp.get('k')}): rand-k "
                f"{cp.get('rounds_per_sec', 0):.2f} r/s vs "
                f"{cp.get('rounds_per_sec_dense', 0):.2f} dense "
                f"({cp.get('randk_relative_to_dense', 0):.2f}x), bytes "
                f"{cp.get('bytes_reduction_randk', 0):.0f}x / "
                f"{cp.get('bytes_reduction_sketch', 0):.0f}x smaller "
                f"(rand-k / sketch), lossless-leg {parity}")
        sh = cp.get("sharded")
        if sh:
            line += (f"; sharded ({sh.get('shards')} shards) rand-k "
                     f"{sh.get('randk_relative_to_dense', 0):.2f}x dense")
        print(line)

    ns = rep.get("noise_schedule")
    if ns:
        conv = ("converges" if ns.get("final_dist_within_2x_fixed")
                else "CONVERGENCE DRIFT")
        print(f"\n**Noise schedule (e7, §17)** (decay={ns.get('decay')}): "
              f"{ns.get('rounds_per_sec', 0):.0f} r/s vs "
              f"{ns.get('rounds_per_sec_fixed', 0):.0f} fixed-sigma "
              f"({ns.get('relative_to_fixed', 0):.2f}x); final dist "
              f"{ns.get('final_dist', 0):.3f} vs "
              f"{ns.get('final_dist_fixed', 0):.3f} fixed ({conv})")

    tl = rep.get("telemetry")
    if tl:
        ok = "ledger==report" if tl.get("ledger_matches_report") else \
            "LEDGER MISMATCH"
        line = (f"\n**Telemetry stream (e7)**: "
                f"{tl.get('rounds_per_sec', 0):.0f} r/s with the tap "
                f"compiled in, eps={tl.get('final_ledger_eps', 0):.3f} "
                f"({ok})")
        # per-round wall clock from the JSONL stream itself (tap-measured)
        try:
            with open(args.telemetry_jsonl) as f:
                times = [o["round_time_s"] for o in map(json.loads, f)
                         if "round_time_s" in o and "event" not in o]
        except (OSError, json.JSONDecodeError):
            times = []
        # drop the first round: it absorbs dispatch/staging warmup
        if len(times) > 2:
            ts = sorted(times[1:])
            med = statistics.median(ts)
            p95 = ts[min(len(ts) - 1, int(0.95 * len(ts)))]
            line += (f"; round time median {1e3*med:.1f} ms, "
                     f"p95 {1e3*p95:.1f} ms over {len(times)} rounds")
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
