"""E1 — Fig. 1 (left): synthetic linear regression, distance to w*.

Paper setting: M=1000 clients, T=50 rounds, tau=20 local steps, d=500 (CDP) /
d=100 (LDP), sigma = 5C/sqrt(M) (CDP), 0.7C (LDP Gaussian),
eps0=eps1=eps2=2 (PrivUnit). Hyperparameters from the paper's grid search
(Table 2). Metric: ||w - w*|| (mean +/- std over seeds).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import mean_std, print_table, write_csv
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim.scaffold import DPScaffoldConfig, run_dp_scaffold
from repro.fedsim.server import run_federated

# (eta_l, C) per algorithm x DP type, selected by re-running the paper's
# grid-search protocol (E.1) on OUR generation (unit-normalized features —
# see EXPERIMENTS.md deviations; the paper's Table 2 values assume their
# unstated feature scale). Grid: eta_l x C over {0.01..1} x {0.1..3}.
HP = {
    "ldp-gauss": {"fedexp": (0.3, 0.3), "fedavg": (0.3, 1.0), "scaffold": (0.3, 0.3)},
    "ldp-privunit": {"fedexp": (0.1, 1.0), "fedavg": (0.3, 3.0), "scaffold": (0.3, 0.3)},
    "cdp": {"fedexp": (0.1, 0.3), "fedavg": (0.3, 3.0), "scaffold": (0.3, 0.3)},
}


def _run_setting(setting: str, alg: str, data, w0, *, rounds, tau, seed):
    m, d = data.x.shape
    eta_l, c = HP[setting][alg]
    key = jax.random.PRNGKey(1000 + seed)
    eval_fn = distance_to_opt(data.w_star)
    if alg == "scaffold":
        central = setting == "cdp"
        sigma = 5 * c / math.sqrt(m) if central else 0.7 * c
        cfg = DPScaffoldConfig(clip_norm=c, sigma=sigma, central=central, num_clients=m)
        r = run_dp_scaffold(cfg, linreg_loss, w0, data.client_batches(),
                            rounds=rounds, tau=tau, eta_l=eta_l, key=key, eval_fn=eval_fn)
        return r
    if setting == "cdp":
        name = "cdp-fedexp" if alg == "fedexp" else "dp-fedavg-cdp"
        algorithm = make_algorithm(name, clip_norm=c, sigma=5 * c / math.sqrt(m),
                                   num_clients=m)
    elif setting == "ldp-gauss":
        name = "ldp-fedexp-gauss" if alg == "fedexp" else "dp-fedavg-ldp-gauss"
        algorithm = make_algorithm(name, clip_norm=c, sigma=0.7 * c)
    else:  # ldp-privunit
        name = "ldp-fedexp-privunit" if alg == "fedexp" else "dp-fedavg-privunit"
        algorithm = make_algorithm(name, clip_norm=c, eps0=2.0, eps1=2.0, eps2=2.0, dim=d)
    return run_federated(algorithm, linreg_loss, w0, data.client_batches(),
                         rounds=rounds, tau=tau, eta_l=eta_l, key=key, eval_fn=eval_fn)


def main(*, clients: int = 400, rounds: int = 30, tau: int = 20, seeds: int = 2):
    """Defaults slightly reduced from the paper's M=1000/T=50/5 seeds to fit
    the single-core CI budget; pass the paper's values explicitly to match."""
    rows = []
    curves = []
    for setting, d in (("cdp", 500), ("ldp-gauss", 100), ("ldp-privunit", 100)):
        data = make_synthetic_linreg(jax.random.PRNGKey(0), clients, d)
        w0 = jnp.zeros(d)
        for alg in ("fedavg", "fedexp", "scaffold"):
            finals, final_dists = [], []
            for s in range(seeds):
                r = _run_setting(setting, alg, data, w0, rounds=rounds, tau=tau, seed=s)
                hist = [float(x) for x in r.metric_history]
                finals.append(hist)
                final_dists.append(float(distance_to_opt(data.w_star)(r.final_w)))
                if s == 0:
                    for t, v in enumerate(hist):
                        curves.append([setting, alg, t, v])
            mu, sd = mean_std(final_dists)
            rows.append([setting, alg, d, mu, sd])
    write_csv("e1_synthetic_curves.csv", ["setting", "algorithm", "round", "dist"], curves)
    write_csv("e1_synthetic_final.csv",
              ["setting", "algorithm", "dim", "final_dist_mean", "final_dist_std"], rows)
    print_table("E1 synthetic linreg: final ||w - w*|| (mean +/- std over seeds)",
                ["setting", "algorithm", "d", "mean", "std"], rows)
    # the paper's claim: FedEXP < FedAvg in every setting
    for setting in ("cdp", "ldp-gauss", "ldp-privunit"):
        exp = next(r[3] for r in rows if r[0] == setting and r[1] == "fedexp")
        avg = next(r[3] for r in rows if r[0] == setting and r[1] == "fedavg")
        tag = "OK " if exp < avg else "WARN"
        print(f"{tag} {setting}: DP-FedEXP {exp:.4f} vs DP-FedAvg {avg:.4f}")
    return rows


if __name__ == "__main__":
    main()
