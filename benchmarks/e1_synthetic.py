"""E1 — Fig. 1 (left): synthetic linear regression, distance to w*.

Paper setting: M=1000 clients, T=50 rounds, tau=20 local steps, d=500 (CDP) /
d=100 (LDP), sigma = 5C/sqrt(M) (CDP), 0.7C (LDP Gaussian),
eps0=eps1=eps2=2 (PrivUnit). Hyperparameters from the paper's grid search
(Table 2). Metric: ||w - w*|| (mean +/- std over seeds).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import make_dp_algorithm, mean_std, print_table, write_csv
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import FederatedSession, TrainSpec
from repro.fedsim.scaffold import DPScaffoldConfig, run_dp_scaffold
from repro.fedsim.server import RunResult

# (eta_l, C) per algorithm x DP type, selected by re-running the paper's
# grid-search protocol (E.1) on OUR generation (unit-normalized features —
# see EXPERIMENTS.md deviations; the paper's Table 2 values assume their
# unstated feature scale). Grid: eta_l x C over {0.01..1} x {0.1..3}.
HP = {
    "ldp-gauss": {"fedexp": (0.3, 0.3), "fedavg": (0.3, 1.0), "scaffold": (0.3, 0.3)},
    "ldp-privunit": {"fedexp": (0.1, 1.0), "fedavg": (0.3, 3.0), "scaffold": (0.3, 0.3)},
    "cdp": {"fedexp": (0.1, 0.3), "fedavg": (0.3, 3.0), "scaffold": (0.3, 0.3)},
}


def _make_algorithm(setting: str, alg: str, m: int, d: int):
    _, c = HP[setting][alg]
    return make_dp_algorithm(setting, alg, clip=c, clients=m, dim=d)


def _run_setting_batched(setting: str, alg: str, data, w0, *, rounds, tau, seeds):
    """All seeds of one (setting, algorithm) cell as ONE batched program
    (scaffold keeps its own loop — its client state lives outside the
    engine)."""
    m, d = data.x.shape
    eta_l, c = HP[setting][alg]
    keys = jnp.stack([jax.random.PRNGKey(1000 + s) for s in range(seeds)])
    eval_fn = distance_to_opt(data.w_star)
    if alg == "scaffold":
        central = setting == "cdp"
        sigma = 5 * c / math.sqrt(m) if central else 0.7 * c
        cfg = DPScaffoldConfig(clip_norm=c, sigma=sigma, central=central, num_clients=m)
        runs = [run_dp_scaffold(cfg, linreg_loss, w0, data.client_batches(),
                                rounds=rounds, tau=tau, eta_l=eta_l, key=keys[s],
                                eval_fn=eval_fn)
                for s in range(seeds)]
        return RunResult(
            final_w=jnp.stack([r.final_w for r in runs]),
            last_w=jnp.stack([r.last_w for r in runs]),
            eta_history=jnp.stack([r.eta_history for r in runs]),
            metric_history=jnp.stack([r.metric_history for r in runs]))
    algorithm = _make_algorithm(setting, alg, m, d)
    session = FederatedSession(algorithm, linreg_loss, w0, data.client_batches(),
                               train=TrainSpec(rounds=rounds, tau=tau, eta_l=eta_l),
                               eval_fn=eval_fn)
    return session.run_batched(keys)


def _run_setting(setting: str, alg: str, data, w0, *, rounds, tau, seed):
    """Single-seed variant (spot checks / external callers) — runs ONLY the
    requested seed."""
    m, d = data.x.shape
    eta_l, c = HP[setting][alg]
    key = jax.random.PRNGKey(1000 + seed)
    eval_fn = distance_to_opt(data.w_star)
    if alg == "scaffold":
        central = setting == "cdp"
        sigma = 5 * c / math.sqrt(m) if central else 0.7 * c
        cfg = DPScaffoldConfig(clip_norm=c, sigma=sigma, central=central, num_clients=m)
        return run_dp_scaffold(cfg, linreg_loss, w0, data.client_batches(),
                               rounds=rounds, tau=tau, eta_l=eta_l, key=key,
                               eval_fn=eval_fn)
    session = FederatedSession(_make_algorithm(setting, alg, m, d), linreg_loss,
                               w0, data.client_batches(),
                               train=TrainSpec(rounds=rounds, tau=tau, eta_l=eta_l),
                               eval_fn=eval_fn)
    return session.run(key)


def main(*, clients: int = 400, rounds: int = 30, tau: int = 20, seeds: int = 2):
    """Defaults slightly reduced from the paper's M=1000/T=50/5 seeds to fit
    the single-core CI budget; pass the paper's values explicitly to match.
    Each (setting, algorithm) cell runs all seeds as one batched program."""
    rows = []
    curves = []
    for setting, d in (("cdp", 500), ("ldp-gauss", 100), ("ldp-privunit", 100)):
        data = make_synthetic_linreg(jax.random.PRNGKey(0), clients, d)
        w0 = jnp.zeros(d)
        for alg in ("fedavg", "fedexp", "scaffold"):
            r = _run_setting_batched(setting, alg, data, w0, rounds=rounds,
                                     tau=tau, seeds=seeds)
            ev = distance_to_opt(data.w_star)
            final_dists = [float(ev(r.final_w[s])) for s in range(seeds)]
            for t, v in enumerate(float(x) for x in r.metric_history[0]):
                curves.append([setting, alg, t, v])
            mu, sd = mean_std(final_dists)
            rows.append([setting, alg, d, mu, sd])
    write_csv("e1_synthetic_curves.csv", ["setting", "algorithm", "round", "dist"], curves)
    write_csv("e1_synthetic_final.csv",
              ["setting", "algorithm", "dim", "final_dist_mean", "final_dist_std"], rows)
    print_table("E1 synthetic linreg: final ||w - w*|| (mean +/- std over seeds)",
                ["setting", "algorithm", "d", "mean", "std"], rows)
    # the paper's claim: FedEXP < FedAvg in every setting
    for setting in ("cdp", "ldp-gauss", "ldp-privunit"):
        exp = next(r[3] for r in rows if r[0] == setting and r[1] == "fedexp")
        avg = next(r[3] for r in rows if r[0] == setting and r[1] == "fedavg")
        tag = "OK " if exp < avg else "WARN"
        print(f"{tag} {setting}: DP-FedEXP {exp:.4f} vs DP-FedAvg {avg:.4f}")
    return rows


if __name__ == "__main__":
    main()
